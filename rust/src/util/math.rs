//! Special functions and small numeric helpers used by the VB engine and
//! the evaluation code.

/// Digamma (psi) function, Bernardo's algorithm AS 103.
/// Accurate to ~1e-12 for x > 0; used by variational Bayes (Blei 2003).
pub fn digamma(mut x: f64) -> f64 {
    debug_assert!(x > 0.0, "digamma domain: x > 0, got {x}");
    let mut result = 0.0;
    // recurrence to push x high enough that the 4-term asymptotic series
    // is accurate to ~1e-12
    while x < 10.0 {
        result -= 1.0 / x;
        x += 1.0;
    }
    let inv = 1.0 / x;
    let inv2 = inv * inv;
    result += x.ln() - 0.5 * inv
        - inv2
            * (1.0 / 12.0
                - inv2
                    * (1.0 / 120.0
                        - inv2 * (1.0 / 252.0 - inv2 * (1.0 / 240.0))));
    result
}

/// log-sum-exp over a slice (stable).
pub fn log_sum_exp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !m.is_finite() {
        return m;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f64>().ln()
}

/// In-place L1 normalization of a non-negative f32 slice; returns the sum.
/// A zero vector becomes uniform.
pub fn normalize_l1(xs: &mut [f32]) -> f32 {
    let sum: f32 = xs.iter().sum();
    if sum > 0.0 {
        let inv = 1.0 / sum;
        for x in xs.iter_mut() {
            *x *= inv;
        }
    } else if !xs.is_empty() {
        let u = 1.0 / xs.len() as f32;
        xs.fill(u);
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digamma_known_values() {
        // psi(1) = -gamma (Euler–Mascheroni)
        assert!((digamma(1.0) + 0.5772156649015329).abs() < 1e-10);
        // psi(0.5) = -gamma - 2 ln 2
        assert!((digamma(0.5) + 1.9635100260214235).abs() < 1e-10);
        // recurrence psi(x+1) = psi(x) + 1/x
        for &x in &[0.1, 1.7, 42.0] {
            assert!((digamma(x + 1.0) - digamma(x) - 1.0 / x).abs() < 1e-10);
        }
    }

    #[test]
    fn lse_matches_naive() {
        let xs = [0.1, -2.0, 3.5];
        let naive: f64 = xs.iter().map(|x: &f64| x.exp()).sum::<f64>().ln();
        assert!((log_sum_exp(&xs) - naive).abs() < 1e-12);
        assert_eq!(log_sum_exp(&[]), f64::NEG_INFINITY);
    }

    #[test]
    fn lse_stable_at_large_magnitudes() {
        let xs = [1000.0, 1000.0];
        assert!((log_sum_exp(&xs) - (1000.0 + 2f64.ln())).abs() < 1e-9);
    }

    #[test]
    fn normalize_l1_cases() {
        let mut xs = [2.0f32, 6.0];
        assert_eq!(normalize_l1(&mut xs), 8.0);
        assert_eq!(xs, [0.25, 0.75]);
        let mut zs = [0.0f32, 0.0, 0.0, 0.0];
        normalize_l1(&mut zs);
        assert_eq!(zs, [0.25; 4]);
    }
}
