//! Shared substrates: RNG, JSON, partial sort, timing, memory accounting,
//! special functions, and a property-test driver. These replace crates
//! (`rand`, `serde_json`, `criterion`, `proptest`) that are unavailable in
//! the offline build environment — see DESIGN.md §Substitutions.

pub mod json;
pub mod math;
pub mod mem;
pub mod partial_sort;
pub mod prop;
pub mod rng;
pub mod timer;
