//! Deterministic wire-fault injection (Contract 9): seeded chaos at
//! frame granularity for the distributed transport.
//!
//! A [`ChaosPlan`] decides, for every frame exchange the master performs,
//! whether that frame suffers a fault — a payload bit-flip, a mid-frame
//! truncation, a dropped frame (the half-open-hang model: the link stays
//! up but the frame never arrives, recovered by the reply deadline), a
//! connection reset, a duplicated frame, or a per-frame delay. Decisions
//! are **stateless**, keyed on `(seed, batch, iter, slot, frame kind,
//! attempt)` exactly like [`FaultPlan`](crate::fault::FaultPlan)'s
//! straggler draws: the plan owns no mutable state, never touches the
//! training RNG, and the same key always yields the same verdict — so a
//! chaos schedule is reproducible from a single `u64` and a recovery
//! replay of an exchange re-encounters exactly the faults its key
//! selects.
//!
//! # Termination
//!
//! The `attempt` component of the key is what makes every chaos schedule
//! *eventually let frames through* (the Contract 9 precondition):
//!
//! * pinned plans ([`ChaosPlan::pinned`]) fire a spec only at
//!   `attempt == 0` — the first transmission of the keyed frame is
//!   faulted, every retransmission is clean;
//! * seeded plans ([`ChaosPlan::seeded`]) may draw faults for the first
//!   [`ChaosPlan::max_attempts`] attempts and pass unconditionally from
//!   then on.
//!
//! The transport's retry budget exceeds `max_attempts`, so a supervised
//! exchange always converges and — by the idempotent-resend protocol
//! (`comm::transport`) — converges to the fault-free bits.

use crate::comm::wire::FrameKind;
use crate::util::rng::Rng;

/// What happens to one frame transmission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosFault {
    /// flip one bit of the encoded frame outside the magic — refused by
    /// the receiver's checksum (or kind/len validation)
    FlipBit,
    /// cut the frame mid-byte-stream and close the connection — the
    /// mid-frame reset: the receiver sees a truncated frame then EOF
    Truncate,
    /// the frame silently never arrives; the link stays up (the
    /// half-open hang, recovered by the reply deadline)
    Drop,
    /// close the connection before the frame is written
    Reset,
    /// the frame arrives twice; the receiver must apply it once
    Duplicate,
    /// the frame arrives late by `ms` wall milliseconds
    Delay {
        ms: u64,
    },
}

/// One pinned fault at a `(batch, iter, slot, frame-kind)` exchange
/// point — the chaos twin of [`FaultSpec`](crate::fault::FaultSpec).
/// `iter` follows the coordinator's numbering: Batch/BatchAck exchanges
/// are iteration 0, Sweep/Gather exchanges use the iteration index t,
/// Fold/FoldPart exchanges use the fold index `iters + 1`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChaosSpec {
    pub batch: usize,
    pub iter: usize,
    pub slot: usize,
    pub kind: FrameKind,
    pub fault: ChaosFault,
}

/// A deterministic, stateless wire-fault schedule.
#[derive(Clone, Debug, Default)]
pub struct ChaosPlan {
    specs: Vec<ChaosSpec>,
    /// `(seed, permille)` for the seeded mode: each `(batch, iter, slot,
    /// kind, attempt)` key under `max_attempts` suffers a fault with
    /// probability `permille / 1000`
    seeded: Option<(u64, u32)>,
    /// attempts `>= max_attempts` always pass — the termination bound
    max_attempts: usize,
}

/// Stateless per-key mixer (splitmix64-style finalizer folded over the
/// key fields) — the only randomness source of the seeded mode, fully
/// separate from the training RNG stream.
fn chaos_key(seed: u64, batch: u64, iter: u64, slot: u64, kind: u32, attempt: u64) -> u64 {
    let mut h = seed ^ 0xC8A0_5FA0_17BA_D5EE;
    for v in [batch, iter, slot, kind as u64, attempt] {
        h ^= v.wrapping_add(0x9E37_79B9_7F4A_7C15);
        h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        h ^= h >> 31;
    }
    h
}

impl ChaosPlan {
    /// A plan from explicit fault points. Each spec fires on the *first*
    /// transmission (`attempt == 0`) of its keyed exchange only — the
    /// pinned-point constructor `chaos_equiv.rs` uses.
    pub fn pinned(specs: Vec<ChaosSpec>) -> ChaosPlan {
        ChaosPlan { specs, seeded: None, max_attempts: 1 }
    }

    /// A seeded plan: every exchange key suffers a uniformly drawn fault
    /// with probability `permille / 1000` (clamped to 1000) on each of
    /// its first two attempts, and passes from attempt 2 on.
    pub fn seeded(seed: u64, permille: u32) -> ChaosPlan {
        ChaosPlan { specs: Vec::new(), seeded: Some((seed, permille.min(1000))), max_attempts: 2 }
    }

    /// The attempt index from which every transmission passes.
    pub fn max_attempts(&self) -> usize {
        self.max_attempts
    }

    /// The pinned schedule (empty for seeded plans).
    pub fn specs(&self) -> &[ChaosSpec] {
        &self.specs
    }

    /// Decide the fate of one frame transmission. Stateless: the same
    /// key always returns the same verdict; nothing is recorded.
    pub fn decide(
        &self,
        batch: usize,
        iter: usize,
        slot: usize,
        kind: FrameKind,
        attempt: usize,
    ) -> Option<ChaosFault> {
        if attempt >= self.max_attempts {
            return None;
        }
        for s in &self.specs {
            if s.batch == batch && s.iter == iter && s.slot == slot && s.kind == kind {
                return Some(s.fault);
            }
        }
        let (seed, permille) = self.seeded?;
        let mut rng = Rng::new(chaos_key(
            seed,
            batch as u64,
            iter as u64,
            slot as u64,
            kind as u32,
            attempt as u64,
        ));
        if (rng.below(1000) as u32) < permille {
            Some(match rng.below(6) {
                0 => ChaosFault::FlipBit,
                1 => ChaosFault::Truncate,
                2 => ChaosFault::Drop,
                3 => ChaosFault::Reset,
                4 => ChaosFault::Duplicate,
                _ => ChaosFault::Delay { ms: 1 + rng.below(25) as u64 },
            })
        } else {
            None
        }
    }
}

/// Flip one deterministic bit of an encoded frame, skipping the 8 magic
/// bytes *and* the 8 length bytes (offsets 12..20): every remaining
/// position — kind, seq, digest, payload — is digest-covered, so the
/// receiver reads exactly the framed byte count and then refuses the
/// frame (checksum or kind defect). A flip in the length field instead
/// could inflate `len` and stall the receiver waiting for bytes that
/// never arrive, which is the half-open hang — modeled separately as
/// [`ChaosFault::Drop`], not as corruption.
pub fn flip_bit(bytes: &mut [u8], salt: u64) {
    if bytes.len() > 20 {
        // eligible positions: [8..12) ∪ [20..len)
        let idx = salt as usize % (bytes.len() - 16);
        let i = if idx < 4 { 8 + idx } else { 16 + idx };
        bytes[i] ^= 1 << (salt % 8);
    } else if let Some(b) = bytes.first_mut() {
        *b ^= 1;
    }
}

/// Deterministic mid-frame cut point: strictly less than `len`, so a
/// truncated write is always an incomplete frame.
pub fn cut_len(len: usize, salt: u64) -> usize {
    if len == 0 {
        0
    } else {
        salt as usize % len
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::wire;

    #[test]
    fn pinned_specs_fire_on_first_attempt_only() {
        let plan = ChaosPlan::pinned(vec![ChaosSpec {
            batch: 1,
            iter: 2,
            slot: 0,
            kind: FrameKind::Sweep,
            fault: ChaosFault::Reset,
        }]);
        assert_eq!(plan.decide(1, 2, 0, FrameKind::Sweep, 0), Some(ChaosFault::Reset));
        // statelessness: the same key keeps answering the same thing
        assert_eq!(plan.decide(1, 2, 0, FrameKind::Sweep, 0), Some(ChaosFault::Reset));
        // every retransmission passes
        assert_eq!(plan.decide(1, 2, 0, FrameKind::Sweep, 1), None);
        // off-key exchanges pass untouched
        assert_eq!(plan.decide(1, 2, 1, FrameKind::Sweep, 0), None);
        assert_eq!(plan.decide(1, 3, 0, FrameKind::Sweep, 0), None);
        assert_eq!(plan.decide(1, 2, 0, FrameKind::Gather, 0), None);
    }

    #[test]
    fn seeded_plans_are_deterministic_and_bounded() {
        let a = ChaosPlan::seeded(99, 500);
        let b = ChaosPlan::seeded(99, 500);
        let mut fired = 0usize;
        for batch in 0..4 {
            for iter in 0..6 {
                for slot in 0..3 {
                    for attempt in 0..4 {
                        let va = a.decide(batch, iter, slot, FrameKind::Sweep, attempt);
                        let vb = b.decide(batch, iter, slot, FrameKind::Sweep, attempt);
                        assert_eq!(va, vb, "seeded draw not deterministic");
                        if attempt >= a.max_attempts() {
                            assert_eq!(va, None, "attempt cap violated");
                        }
                        fired += va.is_some() as usize;
                    }
                }
            }
        }
        // permille 500 over 144 eligible keys: faults certainly fire,
        // and certainly not everywhere
        assert!(fired > 10 && fired < 144, "fired {fired}");
        // a different seed draws a different schedule
        let c = ChaosPlan::seeded(100, 500);
        let diff = (0..40).any(|i| {
            c.decide(i, 1, 0, FrameKind::Sweep, 0) != a.decide(i, 1, 0, FrameKind::Sweep, 0)
        });
        assert!(diff, "seed 99 and 100 drew identical schedules");
        // permille 0 never fires
        let z = ChaosPlan::seeded(99, 0);
        assert_eq!(z.decide(0, 1, 0, FrameKind::Sweep, 0), None);
    }

    #[test]
    fn mangled_frames_are_always_refused() {
        let clean = wire::encode_frame(FrameKind::Gather, 7, &[1, 2, 3, 4, 5, 6, 7, 8]);
        for salt in 0..64u64 {
            let mut flipped = clean.clone();
            flip_bit(&mut flipped, salt);
            assert!(wire::decode_frame(&flipped).is_err(), "flip salt {salt} accepted");
            let cut = cut_len(clean.len(), salt);
            assert!(cut < clean.len());
            assert!(wire::decode_frame(&clean[..cut]).is_err(), "cut salt {salt} accepted");
        }
    }
}
