//! Seeded fault injection for the resilience subsystem (Contract 6).
//!
//! A [`FaultPlan`] deterministically kills or delays logical workers at
//! chosen `(batch, iteration, sync-phase)` points of the training loop.
//! The coordinator consults the plan at three pinned sync-phase
//! boundaries:
//!
//! * [`SyncPhase::Sweep`] — before the doc-parallel sweep of an
//!   iteration starts (the "worker died computing" case; the t = 1
//!   sweep of a batch is the canonical kill point because nothing of
//!   the batch has been communicated yet);
//! * [`SyncPhase::MidReduce`] — *inside* the allreduce boundary
//!   (`comm::allreduce::allreduce_step_injected` and friends): the
//!   owners have folded their slices but the allgather republish has
//!   not completed, so the batch working state is mid-sync and
//!   unusable;
//! * [`SyncPhase::Fold`] — at the end-of-batch fold (iteration index
//!   `iters + 1`, matching the ledger's fold-sync numbering), before
//!   the batch gradient joins the global φ̂.
//!
//! # Semantics
//!
//! * **Kills fire exactly once.** Each [`FaultKind::Kill`] spec carries
//!   a fired flag; after it trips, replays of the same `(batch, iter,
//!   phase)` point pass through. Without this, the recovery loop would
//!   die at the same point forever.
//! * **Delays are stateless** and fire on *every* encounter, including
//!   recovery replays — a deterministic model of a persistently slow
//!   worker. They add simulated seconds to the worker's compute time;
//!   the ledger charges the barrier wait via
//!   [`Ledger::record_straggler`](crate::comm::Ledger::record_straggler).
//! * **Everything derives from the seed.** [`FaultPlan::seeded`] draws
//!   its kill/delay points from [`Rng`], so a fault schedule is
//!   reproducible from a single `u64` — the same property the training
//!   loop itself has (Contract 1).
//!
//! Recovery (coordinator `fit_resilient`) replays the interrupted batch
//! from the last good checkpoint; determinism makes the replay — and
//! therefore the recovered run — bitwise identical to an uninterrupted
//! run (`rust/tests/fault_equiv.rs`).

//! # Real process kills (Contract 8)
//!
//! With the TCP transport, [`FaultKind::Kill`] generalizes from a
//! simulated abort to an actual process death: when a kill trips in the
//! distributed coordinator (`coordinator::dist`), the master
//! [`sigkill`]s the targeted `pobp-worker` process before surfacing
//! `TrainError::Killed`, and recovery respawns the worker and rejoins
//! it through the checkpoint-carrying batch frame. Determinism is
//! unchanged — the plan still decides *where* the death happens — so a
//! SIGKILLed-and-rejoined distributed run ends bitwise identical to an
//! uninterrupted one (`rust/tests/dist_equiv.rs`).

use std::fmt;
use std::io;
use std::process::{Child, ExitStatus};
use std::sync::atomic::{AtomicBool, Ordering};

use crate::util::rng::Rng;

pub mod chaos;

pub use chaos::{ChaosFault, ChaosPlan, ChaosSpec};

/// SIGKILL a real worker process — the process-boundary form of
/// [`FaultKind::Kill`]. `Child::kill` delivers SIGKILL on Unix; the
/// `wait` reaps the zombie so a respawned worker can reuse the slot.
/// Racing an already-exited child is fine: its status is returned.
pub fn sigkill(child: &mut Child) -> io::Result<ExitStatus> {
    if let Some(status) = child.try_wait()? {
        return Ok(status);
    }
    child.kill()?;
    child.wait()
}

/// Where in an iteration's sync cycle a fault fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncPhase {
    /// before the doc-parallel sweep of iteration `iter`
    Sweep,
    /// inside the allreduce boundary: after the owner fold, before the
    /// allgather republish completes
    MidReduce,
    /// at the end-of-batch fold (`iter = iters_run + 1`, the ledger's
    /// fold-sync index)
    Fold,
}

impl SyncPhase {
    pub fn name(&self) -> &'static str {
        match self {
            SyncPhase::Sweep => "sweep",
            SyncPhase::MidReduce => "mid-reduce",
            SyncPhase::Fold => "fold",
        }
    }
}

/// What the fault does to the targeted worker.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// the worker process dies: the run aborts at the fault point and
    /// must be recovered from the last checkpoint
    Kill,
    /// the worker straggles: `secs` of simulated extra compute time at
    /// the iteration's barrier
    Delay {
        /// simulated extra seconds added to the worker's sweep time
        secs: f64,
    },
}

/// One injected fault at a `(batch, iter, phase, worker)` point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    /// mini-batch index m
    pub batch: usize,
    /// iteration t within the batch (fold faults use `iters + 1`)
    pub iter: usize,
    /// sync-phase boundary the fault fires at
    pub phase: SyncPhase,
    /// targeted logical worker (attribution only for kills — the whole
    /// bulk-synchronous step dies with any member)
    pub worker: usize,
    pub kind: FaultKind,
}

/// A fault that actually fired — the error payload a killed run
/// surfaces through `coordinator::TrainError::Killed`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub batch: usize,
    pub iter: usize,
    pub phase: SyncPhase,
    pub worker: usize,
}

impl fmt::Display for FaultEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "worker {} killed at batch {} iter {} ({})",
            self.worker,
            self.batch,
            self.iter,
            self.phase.name()
        )
    }
}

/// A deterministic fault schedule. Kills fire once (interior fired
/// flags — shared through `&self` so the plan can be threaded through
/// the retry loop); delays fire on every encounter.
#[derive(Debug, Default)]
pub struct FaultPlan {
    specs: Vec<FaultSpec>,
    fired: Vec<AtomicBool>,
}

impl FaultPlan {
    /// A plan from explicit fault points (the pinned-point constructor
    /// `fault_equiv.rs` uses).
    pub fn new(specs: Vec<FaultSpec>) -> FaultPlan {
        let fired = specs.iter().map(|_| AtomicBool::new(false)).collect();
        FaultPlan { specs, fired }
    }

    /// A single-kill plan — the common test shape.
    pub fn kill(batch: usize, iter: usize, phase: SyncPhase, worker: usize) -> FaultPlan {
        FaultPlan::new(vec![FaultSpec {
            batch,
            iter,
            phase,
            worker,
            kind: FaultKind::Kill,
        }])
    }

    /// A seeded plan: `kills` kill points drawn uniformly over
    /// `batches × iters × {sweep, mid-reduce, fold} × n_workers`.
    /// Iterations are drawn in `1..=iters`; fold kills use the fold
    /// index `iters + 1` so they land on a boundary the coordinator
    /// actually visits. Deterministic in `seed`.
    pub fn seeded(
        seed: u64,
        n_workers: usize,
        kills: usize,
        batches: usize,
        iters: usize,
    ) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA17_1A5E_D00D_F00D);
        let specs = (0..kills)
            .map(|_| {
                let phase = match rng.below(3) {
                    0 => SyncPhase::Sweep,
                    1 => SyncPhase::MidReduce,
                    _ => SyncPhase::Fold,
                };
                let iter = match phase {
                    SyncPhase::Fold => iters + 1,
                    _ => 1 + rng.below(iters.max(1)),
                };
                FaultSpec {
                    batch: rng.below(batches.max(1)),
                    iter,
                    phase,
                    worker: rng.below(n_workers.max(1)),
                    kind: FaultKind::Kill,
                }
            })
            .collect();
        FaultPlan::new(specs)
    }

    /// The underlying schedule.
    pub fn specs(&self) -> &[FaultSpec] {
        &self.specs
    }

    /// Kill specs that have not fired yet.
    pub fn kills_remaining(&self) -> usize {
        self.specs
            .iter()
            .zip(&self.fired)
            .filter(|(s, f)| {
                matches!(s.kind, FaultKind::Kill) && !f.load(Ordering::SeqCst)
            })
            .count()
    }

    /// Consult the plan at a sync-phase boundary: if an unfired kill
    /// matches `(batch, iter, phase)`, mark it fired and return the
    /// event. The swap makes each kill fire exactly once even across
    /// recovery replays of the same point.
    pub fn trip(
        &self,
        batch: usize,
        iter: usize,
        phase: SyncPhase,
    ) -> Result<(), FaultEvent> {
        for (spec, fired) in self.specs.iter().zip(&self.fired) {
            if matches!(spec.kind, FaultKind::Kill)
                && spec.batch == batch
                && spec.iter == iter
                && spec.phase == phase
                && !fired.swap(true, Ordering::SeqCst)
            {
                return Err(FaultEvent {
                    batch,
                    iter,
                    phase,
                    worker: spec.worker,
                });
            }
        }
        Ok(())
    }

    /// Per-worker simulated delay seconds at `(batch, iter)` — `None`
    /// when no delay spec matches. Delays are stateless: a recovery
    /// replay of the iteration experiences them again.
    pub fn delays_at(
        &self,
        batch: usize,
        iter: usize,
        n_workers: usize,
    ) -> Option<Vec<f64>> {
        let mut out: Option<Vec<f64>> = None;
        for spec in &self.specs {
            if let FaultKind::Delay { secs } = spec.kind {
                if spec.batch == batch && spec.iter == iter && spec.worker < n_workers {
                    out.get_or_insert_with(|| vec![0.0; n_workers])[spec.worker] += secs;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kill_fires_exactly_once() {
        let plan = FaultPlan::kill(2, 3, SyncPhase::MidReduce, 1);
        // wrong points pass through
        assert!(plan.trip(2, 3, SyncPhase::Sweep).is_ok());
        assert!(plan.trip(1, 3, SyncPhase::MidReduce).is_ok());
        assert_eq!(plan.kills_remaining(), 1);
        // the pinned point fires once ...
        let ev = plan.trip(2, 3, SyncPhase::MidReduce).unwrap_err();
        assert_eq!(
            ev,
            FaultEvent { batch: 2, iter: 3, phase: SyncPhase::MidReduce, worker: 1 }
        );
        // ... and the recovery replay of the same point passes
        assert!(plan.trip(2, 3, SyncPhase::MidReduce).is_ok());
        assert_eq!(plan.kills_remaining(), 0);
    }

    #[test]
    fn seeded_plans_are_deterministic() {
        let a = FaultPlan::seeded(99, 4, 5, 10, 8);
        let b = FaultPlan::seeded(99, 4, 5, 10, 8);
        assert_eq!(a.specs(), b.specs());
        assert_eq!(a.specs().len(), 5);
        for s in a.specs() {
            assert!(s.batch < 10);
            assert!(s.worker < 4);
            match s.phase {
                SyncPhase::Fold => assert_eq!(s.iter, 9),
                _ => assert!(s.iter >= 1 && s.iter <= 8),
            }
        }
        let c = FaultPlan::seeded(100, 4, 5, 10, 8);
        assert_ne!(a.specs(), c.specs(), "different seeds, different plans");
    }

    #[test]
    fn delays_accumulate_per_worker_and_are_stateless() {
        let plan = FaultPlan::new(vec![
            FaultSpec {
                batch: 0,
                iter: 2,
                phase: SyncPhase::Sweep,
                worker: 1,
                kind: FaultKind::Delay { secs: 0.5 },
            },
            FaultSpec {
                batch: 0,
                iter: 2,
                phase: SyncPhase::Sweep,
                worker: 1,
                kind: FaultKind::Delay { secs: 0.25 },
            },
        ]);
        assert!(plan.delays_at(0, 1, 3).is_none());
        let d = plan.delays_at(0, 2, 3).unwrap();
        assert_eq!(d, vec![0.0, 0.75, 0.0]);
        // stateless: a replay sees the same delays
        assert_eq!(plan.delays_at(0, 2, 3).unwrap(), d);
        // a delay never trips the kill path
        assert!(plan.trip(0, 2, SyncPhase::Sweep).is_ok());
    }
}
