//! Contract 8 acceptance: the distributed coordinator is bitwise
//! interchangeable with the in-process oracle.
//!
//! * `fit_dist` over the in-process transport (every payload through
//!   the frame codec) must equal `fit` — model bits, residual history,
//!   pair counts, sync schedule, modeled per-segment comm seconds,
//!   snapshot models — across worker counts, storage modes and thread
//!   budgets.
//! * `fit_dist` over **real TCP worker processes** (master + 2/3/4
//!   loopback `pobp-worker`s, spawned from the built binary) must equal
//!   the same oracle, in both `PhiStorageMode`s at thread budgets 1/2.
//! * A `FaultPlan::kill` now SIGKILLs an actual worker process at the
//!   sweep / mid-reduce / fold boundary; `fit_dist_resilient` respawns
//!   the cluster, resumes from the newest checkpoint, and must end
//!   bitwise equal to an uninterrupted run.
//!
//! Only deterministic quantities are compared: wall-measured compute
//! and `total_secs()` legitimately differ between runs and are never
//! asserted; the measured wire seconds are asserted *present*, not
//! equal.

use std::path::PathBuf;

use pobp::comm::transport::{InProcessTransport, TcpSpawnSpec, TcpTransport, Transport};
use pobp::coordinator::{
    fit, fit_dist, fit_dist_resilient, PobpConfig, ResilienceConfig,
};
use pobp::engine::traits::{LdaParams, TrainResult};
use pobp::fault::{FaultPlan, SyncPhase};
use pobp::sched::PowerParams;
use pobp::storage::PhiStorageMode;
use pobp::synth::{generate, SynthSpec};

fn params() -> LdaParams {
    LdaParams::paper(8)
}

/// nnz_budget 600 guarantees a multi-batch run on the tiny corpus at
/// n = 2 (pinned by the coordinator's own `ledger_charges_final_fold_sync`);
/// converge_thresh 0 pins the iteration count; snapshot_every exercises
/// the snapshot path mid-batch.
fn cfg_for(n_workers: usize, threads: usize, storage: PhiStorageMode) -> PobpConfig {
    PobpConfig {
        n_workers,
        max_threads: threads,
        nnz_budget: 600,
        power: PowerParams::paper_default(),
        max_iters: 7,
        converge_thresh: 0.0,
        snapshot_every: 3,
        storage,
        ..Default::default()
    }
}

fn worker_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_pobp-worker"))
}

/// The full deterministic-quantity pin: model bits, residual history,
/// sync/byte schedule, modeled per-segment comm seconds, snapshot
/// model bits. Never wall-measured seconds.
fn assert_equiv(dist: &TrainResult, oracle: &TrainResult, ctx: &str) {
    assert_eq!(dist.model.phi_wk, oracle.model.phi_wk, "model diverged at {ctx}");
    assert_eq!(dist.history.len(), oracle.history.len(), "history len at {ctx}");
    for (a, b) in dist.history.iter().zip(&oracle.history) {
        assert_eq!((a.batch, a.iter), (b.batch, b.iter), "schedule at {ctx}");
        assert_eq!(
            a.residual_per_token.to_bits(),
            b.residual_per_token.to_bits(),
            "batch {} iter {} residual diverged at {ctx}",
            a.batch,
            a.iter
        );
        assert_eq!(a.synced_pairs, b.synced_pairs, "pairs at {ctx}");
    }
    assert_eq!(dist.ledger.sync_count(), oracle.ledger.sync_count(), "{ctx}");
    assert_eq!(
        dist.ledger.payload_bytes_total(),
        oracle.ledger.payload_bytes_total(),
        "{ctx}"
    );
    assert_eq!(dist.ledger.wire_bytes, oracle.ledger.wire_bytes, "{ctx}");
    for (a, b) in dist.ledger.events.iter().zip(&oracle.ledger.events) {
        assert_eq!((a.batch, a.iter), (b.batch, b.iter), "event schedule at {ctx}");
        assert_eq!(a.payload_bytes, b.payload_bytes, "{ctx}");
        assert_eq!(a.comm_secs.to_bits(), b.comm_secs.to_bits(), "{ctx}");
        assert_eq!(
            a.reduce_scatter_secs.to_bits(),
            b.reduce_scatter_secs.to_bits(),
            "{ctx}"
        );
        assert_eq!(a.allgather_secs.to_bits(), b.allgather_secs.to_bits(), "{ctx}");
    }
    assert_eq!(dist.snapshots.len(), oracle.snapshots.len(), "snapshots at {ctx}");
    for ((_, a), (_, b)) in dist.snapshots.iter().zip(&oracle.snapshots) {
        // the f64 element is simulated time (includes measured compute);
        // only the model bits are deterministic
        assert_eq!(a.phi_wk, b.phi_wk, "snapshot model diverged at {ctx}");
    }
}

#[test]
fn inprocess_dist_bitwise_equals_fit_all_modes_and_budgets() {
    for &storage in &[PhiStorageMode::Replicated, PhiStorageMode::Sharded] {
        for &n in &[2usize, 3] {
            for &threads in &[1usize, 2] {
                let corpus = generate(&SynthSpec::tiny(29)).corpus;
                let cfg = cfg_for(n, threads, storage);
                let oracle = fit(&corpus, &params(), &cfg);
                let mut tp = InProcessTransport::new(n, threads);
                let r = fit_dist(&corpus, &params(), &cfg, &mut tp)
                    .expect("in-process dist fit");
                let ctx = format!("inprocess n={n} threads={threads} {storage:?}");
                assert_equiv(&r, &oracle, &ctx);
                // every sync carried a measured wire segment beside the
                // α–β estimate (fold included), and the side totals
                // stayed out of the deterministic comparisons above
                assert_eq!(r.ledger.measured.len(), r.ledger.sync_count(), "{ctx}");
            }
        }
    }
}

#[test]
fn tcp_loopback_dist_bitwise_equals_fit() {
    for &storage in &[PhiStorageMode::Replicated, PhiStorageMode::Sharded] {
        for &n in &[2usize, 3, 4] {
            for &threads in &[1usize, 2] {
                let corpus = generate(&SynthSpec::tiny(31)).corpus;
                let cfg = cfg_for(n, threads, storage);
                let oracle = fit(&corpus, &params(), &cfg);
                let mut tp = TcpTransport::spawn(
                    n,
                    TcpSpawnSpec { exe: worker_exe(), threads },
                )
                .expect("spawn loopback workers");
                let r = fit_dist(&corpus, &params(), &cfg, &mut tp)
                    .expect("tcp dist fit");
                tp.shutdown().expect("clean worker shutdown");
                let ctx = format!("tcp n={n} threads={threads} {storage:?}");
                assert_equiv(&r, &oracle, &ctx);
                assert_eq!(r.ledger.measured.len(), r.ledger.sync_count(), "{ctx}");
                assert!(
                    r.ledger.measured_reduce_secs > 0.0,
                    "tcp run measured no reduce wire time at {ctx}"
                );
            }
        }
    }
}

/// Real process kills: the planned fault SIGKILLs an actual worker at
/// the sweep / mid-reduce / fold boundary, the resilient loop respawns
/// the cluster and resumes from the newest good checkpoint, and the
/// recovered run is bitwise equal to an uninterrupted one.
#[test]
fn tcp_worker_sigkill_and_rejoin_bitwise_equals_uninterrupted() {
    let max_iters = 7;
    let kills = [
        (SyncPhase::Sweep, 1usize, 2usize, 1usize),
        (SyncPhase::MidReduce, 1, 3, 0),
        (SyncPhase::Fold, 1, max_iters + 1, 1),
    ];
    for &storage in &[PhiStorageMode::Replicated, PhiStorageMode::Sharded] {
        for &(phase, batch, iter, worker) in &kills {
            let corpus = generate(&SynthSpec::tiny(37)).corpus;
            let cfg = cfg_for(2, 1, storage);
            let oracle = fit(&corpus, &params(), &cfg);
            let dir = std::env::temp_dir().join(format!(
                "pobp-dist-equiv-{}-{phase:?}-{storage:?}",
                std::process::id()
            ));
            let _ = std::fs::remove_dir_all(&dir);
            let res = ResilienceConfig::in_dir(&dir);
            let faults = FaultPlan::kill(batch, iter, phase, worker);
            let mut tp = TcpTransport::spawn(
                2,
                TcpSpawnSpec { exe: worker_exe(), threads: 1 },
            )
            .expect("spawn loopback workers");
            let r = fit_dist_resilient(
                &corpus,
                &params(),
                &cfg,
                &res,
                Some(&faults),
                &mut tp,
            )
            .expect("resilient dist fit");
            tp.shutdown().expect("clean worker shutdown");
            let ctx = format!("kill {phase:?} at ({batch},{iter}) {storage:?}");
            assert_equiv(&r, &oracle, &ctx);
            assert_eq!(r.ledger.recovery_count, 1, "{ctx}");
            assert!(r.ledger.checkpoint_count >= 1, "{ctx}");
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

/// The in-process resilient wrapper over a transport: same contract,
/// no real processes involved (the kill is purely simulated), so this
/// also pins that `fit_dist_resilient` without faults is a no-op shim.
#[test]
fn inprocess_dist_resilient_healthy_run_matches_oracle() {
    let corpus = generate(&SynthSpec::tiny(41)).corpus;
    let cfg = cfg_for(2, 1, PhiStorageMode::Replicated);
    let oracle = fit(&corpus, &params(), &cfg);
    let dir = std::env::temp_dir()
        .join(format!("pobp-dist-equiv-healthy-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let res = ResilienceConfig::in_dir(&dir);
    let mut tp = InProcessTransport::new(2, 1);
    let r = fit_dist_resilient(&corpus, &params(), &cfg, &res, None, &mut tp)
        .expect("resilient dist fit");
    assert_equiv(&r, &oracle, "inprocess resilient healthy");
    assert_eq!(r.ledger.recovery_count, 0);
    assert!(r.ledger.checkpoint_count >= 1);
    let _ = std::fs::remove_dir_all(&dir);
}
