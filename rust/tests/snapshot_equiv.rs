//! Equivalence and drift tests for the incremental φ̂ snapshot engine
//! (`engine::snapshot`) against the retained clone-and-rebuild oracle:
//!
//! * driving two identical shards through the same sweep sequence — one
//!   reading the [`PhiSnapshot`] (resync every publish), one reading a
//!   fresh [`clone_rebuild`] each iteration — must produce **bitwise
//!   identical** state (μ, θ̂, Δφ̂, r, residuals) across full and
//!   power-subset selections at thread budgets 1/2/8;
//! * the frozen view must equal the source matrix bitwise after every
//!   publish, resync or not — the clone the old ABP loop made;
//! * sparse f64 totals deltas must stay within f64-rounding distance of
//!   a from-scratch rebuild, and a dense resync must restore bitwise
//!   equality with the oracle's totals (drift test alternating sparse
//!   updates with periodic resyncs);
//! * a whole ABP run on the snapshot path (power selection + doc
//!   scheduling + block-table reuse) is bitwise deterministic.

use pobp::comm::Cluster;
use pobp::engine::abp::{fit_abp, AbpConfig};
use pobp::engine::bp::{Selection, ShardBp};
use pobp::engine::snapshot::{clone_rebuild, PhiSnapshot};
use pobp::engine::traits::LdaParams;
use pobp::sched::{select_power, PowerParams};
use pobp::synth::{generate, SynthSpec};
use pobp::util::rng::Rng;

fn twin_shards(seed: u64, k: usize) -> (ShardBp, ShardBp, LdaParams) {
    let corpus = generate(&SynthSpec::tiny(seed)).corpus;
    let params = LdaParams::paper(k);
    let mut rng_a = Rng::new(seed);
    let mut rng_b = Rng::new(seed);
    let a = ShardBp::init(corpus.clone(), k, &mut rng_a);
    let b = ShardBp::init(corpus, k, &mut rng_b);
    (a, b, params)
}

/// Drive the snapshot path and the clone-and-rebuild oracle path through
/// the same sweep sequence and assert bitwise equality every iteration.
fn snapshot_vs_oracle_case(threads: usize, power: Option<PowerParams>, seed: u64) {
    let k = 8;
    let (mut sa, mut sb, params) = twin_shards(seed, k);
    let w = sa.data.w;
    let pool = Cluster::new(1, 0);
    // resync_every = 1: the snapshot's totals are rebuilt from scratch on
    // every publish — the whole trajectory is bitwise the oracle's
    let mut snap = PhiSnapshot::new(&sa.dphi, k, 1);
    let mut selection = Selection::full(w);

    for t in 0..8 {
        // the oracle: what the old ABP loop did every iteration
        let (phi_o, tot_o) = clone_rebuild(&sb.dphi, k);
        let ctx = format!("t={t}, threads={threads}");
        assert_eq!(snap.phi(), &phi_o[..], "frozen view diverged at {ctx}");
        assert_eq!(snap.phi_tot(), &tot_o[..], "totals diverged at {ctx}");

        let (ra, _) = sa.sweep_parallel(
            &pool, threads, snap.phi(), snap.phi_tot(), &selection, &params, true,
        );
        let (rb, _) =
            sb.sweep_parallel(&pool, threads, &phi_o, &tot_o, &selection, &params, true);
        assert_eq!(ra.to_bits(), rb.to_bits(), "residual diverged at {ctx}");
        assert_eq!(sa.mu, sb.mu, "mu diverged at {ctx}");
        assert_eq!(sa.theta, sb.theta, "theta diverged at {ctx}");
        assert_eq!(sa.dphi, sb.dphi, "dphi diverged at {ctx}");
        assert_eq!(sa.r, sb.r, "r diverged at {ctx}");

        // publish the sweep into the snapshot — O(selected pairs + W)
        snap.apply(&sa.dphi, &selection);

        if let Some(pp) = &power {
            let ps = select_power(&sa.r, w, k, pp);
            selection = Selection::from_power(&ps, w);
        }
    }
}

#[test]
fn snapshot_matches_oracle_full_selection_budgets_1_2_8() {
    for &threads in &[1usize, 2, 8] {
        snapshot_vs_oracle_case(threads, None, 41);
    }
}

#[test]
fn snapshot_matches_oracle_power_selection_budgets_1_2_8() {
    for &threads in &[1usize, 2, 8] {
        snapshot_vs_oracle_case(
            threads,
            Some(PowerParams { lambda_w: 0.25, lambda_k_times_k: 4 }),
            43,
        );
    }
}

/// Sparse-delta drift: without any resync the frozen view still equals
/// the source bitwise, and the f64 totals stay within rounding distance
/// of a from-scratch rebuild; with a cadence, every resync restores
/// bitwise equality with the oracle's totals.
#[test]
fn sparse_deltas_drift_bounded_and_resyncs_exact() {
    let k = 8;
    let (mut shard, _, params) = twin_shards(47, k);
    let w = shard.data.w;
    let pool = Cluster::new(1, 0);
    let pp = PowerParams { lambda_w: 0.2, lambda_k_times_k: 3 };

    // warm up with one full sweep so residuals are non-trivial
    let mut never = PhiSnapshot::new(&shard.dphi, k, 0);
    let full = Selection::full(w);
    shard.sweep_parallel(&pool, 0, never.phi(), never.phi_tot(), &full, &params, true);
    never.apply_dense(&shard.dphi);

    let cadence = 3;
    let mut cadenced = never.clone();
    cadenced.resync_every = cadence;

    let mut selection = {
        let ps = select_power(&shard.r, w, k, &pp);
        Selection::from_power(&ps, w)
    };
    for i in 0..24 {
        shard.clear_selected_residuals(&selection);
        shard.sweep_selected(never.phi(), never.phi_tot(), &selection, &params, true);
        never.apply_selected(&shard.dphi, &selection);
        cadenced.apply_selected(&shard.dphi, &selection);

        // the frozen view is exact on both, resync or not
        assert_eq!(never.phi(), &shard.dphi[..], "view diverged at {i}");
        assert_eq!(cadenced.phi(), &shard.dphi[..], "cadenced view diverged at {i}");
        // sparse-delta totals: f64-rounding-level drift only
        assert!(never.totals_drift() < 1e-8, "drift {} at {i}", never.totals_drift());
        if (i + 1) % cadence == 0 {
            // the resync just fired: totals from scratch — bitwise the
            // oracle's, zero drift
            assert_eq!(cadenced.totals_drift(), 0.0, "resync missed at {i}");
            let (_, tot_o) = clone_rebuild(&shard.dphi, k);
            assert_eq!(cadenced.phi_tot(), &tot_o[..], "resync totals at {i}");
        }

        let ps = select_power(&shard.r, w, k, &pp);
        selection = Selection::from_power(&ps, w);
    }
}

/// Whole-run determinism pin on the new path: ABP with power selection,
/// doc scheduling, sparse snapshot publishes with periodic resyncs, and
/// the fixed-block reuse path all active — two runs agree bitwise on the
/// history and the model.
#[test]
fn abp_whole_run_bitwise_deterministic_on_snapshot_path() {
    let corpus = generate(&SynthSpec::tiny(53)).corpus;
    let params = LdaParams::paper(8);
    let cfg = AbpConfig {
        lambda_d: 0.95, // above the default coverage threshold: reuse path
        power: PowerParams { lambda_w: 0.3, lambda_k_times_k: 4 },
        max_iters: 14,
        converge_thresh: 0.0,
        resync_every: 4,
        ..Default::default()
    };
    let a = fit_abp(&corpus, &params, &cfg);
    let b = fit_abp(&corpus, &params, &cfg);
    assert_eq!(a.history.len(), b.history.len());
    for (x, y) in a.history.iter().zip(&b.history) {
        assert_eq!(
            x.residual_per_token.to_bits(),
            y.residual_per_token.to_bits(),
            "iter {} residual diverged",
            x.iter
        );
    }
    assert_eq!(a.model.phi_wk, b.model.phi_wk);
}
