//! Cross-layer parity: the AOT-compiled XLA sweep (L2 JAX + L1 Pallas)
//! must produce the same numbers as the native Rust sparse engine for the
//! identical inputs — this is the test that proves the three layers
//! implement one contract.
//!
//! Requires `make artifacts`; tests skip (with a notice) when the
//! artifacts are absent so `cargo test` stays runnable from a clean tree.
//! The whole target is gated on the `xla` feature (see Cargo.toml).

use std::path::PathBuf;

use pobp::corpus::Csr;
use pobp::engine::bp::{Selection, ShardBp};
use pobp::engine::traits::LdaParams;
use pobp::runtime::{Manifest, SweepArgs, SweepExecutable};
use pobp::sched::{select_power, PowerParams};
use pobp::util::rng::Rng;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Build a shard and its dense mirror with *identical* messages.
struct Mirror {
    shard: ShardBp,
    x: Vec<f32>,
    mu: Vec<f32>,
    d_pad: usize,
    w_pad: usize,
    k: usize,
}

fn make_mirror(seed: u64, d_pad: usize, w_pad: usize, k: usize) -> Mirror {
    let mut rng = Rng::new(seed);
    let docs = d_pad.min(12);
    let w = w_pad.min(40);
    let rows: Vec<Vec<(u32, f32)>> = (0..docs)
        .map(|_| {
            (0..rng.range(3, 10))
                .map(|_| (rng.below(w) as u32, rng.range(1, 4) as f32))
                .collect()
        })
        .collect();
    // the shard sees the padded vocabulary so phi rows align
    let data = Csr::from_docs(w_pad, &rows);
    let shard = ShardBp::init(data, k, &mut rng);

    // dense mirrors with the *same* message values on active entries and
    // uniform elsewhere (inactive entries never move in either engine)
    let mut x = vec![0f32; d_pad * w_pad];
    let mut mu = vec![1.0 / k as f32; d_pad * w_pad * k];
    for d in 0..shard.data.docs() {
        for idx in shard.data.row_range(d) {
            let wi = shard.data.col[idx] as usize;
            x[d * w_pad + wi] = shard.data.val[idx];
            mu[(d * w_pad + wi) * k..(d * w_pad + wi + 1) * k]
                .copy_from_slice(&shard.mu[idx * k..(idx + 1) * k]);
        }
    }
    Mirror { shard, x, mu, d_pad, w_pad, k }
}

fn assert_close(name: &str, got: &[f32], want: &[f32], tol: f32) {
    assert_eq!(got.len(), want.len(), "{name} length");
    let mut worst = 0f32;
    let mut at = 0usize;
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let d = (g - w).abs() / w.abs().max(1.0);
        if d > worst {
            worst = d;
            at = i;
        }
    }
    assert!(
        worst <= tol,
        "{name}: rel diff {worst} at {at}: {} vs {}",
        got[at],
        want[at]
    );
}

fn parity_case(power: Option<PowerParams>, seed: u64) {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping parity test: run `make artifacts`");
        return;
    }
    let m = Manifest::load(&dir).unwrap();
    let e = m.fit(32, 256, 16).expect("ci artifact").clone();
    let exe = SweepExecutable::load(&e).unwrap();
    let (d_pad, w_pad, k) = (e.d, e.w, e.k);
    let params = LdaParams { k, alpha: e.alpha as f32, beta: e.beta as f32 };

    let mut mir = make_mirror(seed, d_pad, w_pad, k);

    // two sweeps so the second runs with non-trivial phi and (optionally)
    // a power selection derived from real residuals
    let mut phi_prev = vec![0f32; w_pad * k];
    let mut word_mask = vec![1f32; w_pad];
    let mut topic_mask = vec![1f32; w_pad * k];
    let mut selection = Selection::full(w_pad);

    for step in 0..2 {
        // --- native sweep ---
        // global phi for the N=1 case: phi_prev + own dphi
        let mut phi_native = phi_prev.clone();
        for (p, &g) in phi_native.iter_mut().zip(&mir.shard.dphi) {
            *p += g;
        }
        let mut phi_tot = vec![0f32; k];
        for row in phi_native.chunks_exact(k) {
            for (t, &v) in row.iter().enumerate() {
                phi_tot[t] += v;
            }
        }
        mir.shard.clear_selected_residuals(&selection);
        mir.shard.sweep(&phi_native, &phi_tot, &selection, &params, true);

        // --- XLA sweep on the mirrored inputs ---
        let out = exe
            .run(&SweepArgs {
                x: &mir.x,
                mu: &mir.mu,
                phi_prev: &phi_prev,
                word_mask: &word_mask,
                topic_mask: &topic_mask,
            })
            .unwrap();

        // compare messages on active entries
        let mut mu_native_dense = mir.mu.clone();
        for d in 0..mir.shard.data.docs() {
            for idx in mir.shard.data.row_range(d) {
                let wi = mir.shard.data.col[idx] as usize;
                mu_native_dense[(d * mir.w_pad + wi) * k
                    ..(d * mir.w_pad + wi + 1) * k]
                    .copy_from_slice(&mir.shard.mu[idx * k..(idx + 1) * k]);
            }
        }
        assert_close(&format!("mu step {step}"), &out.mu, &mu_native_dense, 2e-4);
        assert_close(&format!("dphi step {step}"), &out.dphi, &mir.shard.dphi, 2e-4);
        // residuals: compare only on selected pairs (native keeps stale
        // values elsewhere by design)
        for (i, (&g, &w)) in out.r_wk.iter().zip(&mir.shard.r).enumerate() {
            let sel = word_mask[i / k] > 0.0 && topic_mask[i] > 0.0;
            if sel {
                assert!(
                    (g - w).abs() <= 2e-4 * w.abs().max(1.0),
                    "r pair {i}: {g} vs {w}"
                );
            }
        }

        // carry state into step 2
        mir.mu = out.mu;
        if let Some(pp) = &power {
            let ps = select_power(&mir.shard.r, w_pad, k, pp);
            selection = Selection::from_power(&ps, w_pad);
            word_mask.fill(0.0);
            topic_mask.fill(0.0);
            for (i, &wi) in ps.words.iter().enumerate() {
                word_mask[wi as usize] = 1.0;
                for &tt in &ps.topics[i] {
                    topic_mask[wi as usize * k + tt as usize] = 1.0;
                }
            }
        }
        let _ = &phi_prev; // phi_prev unchanged within one mini-batch
    }
}

#[test]
fn full_selection_parity() {
    parity_case(None, 11);
}

#[test]
fn power_selection_parity() {
    parity_case(Some(PowerParams { lambda_w: 0.2, lambda_k_times_k: 5 }), 12);
}

#[test]
fn xla_obp_end_to_end_learns() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // corpus within the ci artifact's (32, 256) shape
    let mut rng = Rng::new(5);
    let rows: Vec<Vec<(u32, f32)>> = (0..64)
        .map(|i| {
            let base = if i % 2 == 0 { 0u32 } else { 64 };
            (0..10)
                .map(|_| (base + rng.below(64) as u32, 1.0))
                .collect()
        })
        .collect();
    let corpus = Csr::from_docs(256, &rows);
    let params = LdaParams::paper(16);
    let r = pobp::runtime::xla_engine::fit_obp_xla(
        &corpus,
        &params,
        &dir,
        &pobp::runtime::xla_engine::XlaObpConfig {
            max_iters: 20,
            ..Default::default()
        },
    )
    .unwrap();
    assert!((r.model.mass() - corpus.tokens()).abs() < corpus.tokens() * 1e-3);
    let p = pobp::eval::perplexity::heldin_perplexity(&r.model, &corpus, &params);
    // two disjoint 64-word blocks: a good model approaches ~64, uniform is 128
    assert!(p < 100.0, "xla obp failed to learn: perplexity {p}");
}
