//! Golden-vector test: the native Rust sweep must reproduce the pure-jnp
//! oracle (`python/compile/kernels/ref.py`) on a pinned case exported by
//! `python -m tests.export_golden`. This pins the cross-language contract
//! without needing Python or artifacts at `cargo test` time.

use std::path::PathBuf;

use pobp::corpus::Csr;
use pobp::engine::bp::{Selection, ShardBp};
use pobp::engine::traits::LdaParams;
use pobp::util::json::Json;
use pobp::util::rng::Rng;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("python/tests/golden_sweep.json")
}

fn floats(j: &Json, key: &str) -> Vec<f32> {
    j.get(key)
        .and_then(|v| v.as_arr())
        .unwrap_or_else(|| panic!("golden missing {key}"))
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect()
}

#[test]
fn native_sweep_matches_python_oracle() {
    let Ok(text) = std::fs::read_to_string(golden_path()) else {
        // like xla_parity: skip with a notice so `cargo test` stays
        // runnable from a tree without the Python-exported vectors
        eprintln!("skipping golden test: run `python -m tests.export_golden`");
        return;
    };
    let g = Json::parse(&text).unwrap();
    let d = g.get("d").unwrap().as_usize().unwrap();
    let w = g.get("w").unwrap().as_usize().unwrap();
    let k = g.get("k").unwrap().as_usize().unwrap();
    let params = LdaParams {
        k,
        alpha: g.get("alpha").unwrap().as_f64().unwrap() as f32,
        beta: g.get("beta").unwrap().as_f64().unwrap() as f32,
    };
    let x = floats(&g, "x");
    let mu_in = floats(&g, "mu");
    let phi_prev = floats(&g, "phi_prev");
    let want_mu = floats(&g, "mu_out");
    let want_theta = floats(&g, "theta_out");
    let want_dphi = floats(&g, "dphi_out");
    let want_r = floats(&g, "r_wk_out");

    // build the sparse shard from the dense golden inputs
    let docs: Vec<Vec<(u32, f32)>> = (0..d)
        .map(|dd| {
            (0..w)
                .filter(|&ww| x[dd * w + ww] > 0.0)
                .map(|ww| (ww as u32, x[dd * w + ww]))
                .collect()
        })
        .collect();
    let data = Csr::from_docs(w, &docs);
    let mut rng = Rng::new(0);
    let mut shard = ShardBp::init(data, k, &mut rng);
    // overwrite the random messages with the golden ones (active entries)
    for dd in 0..shard.data.docs() {
        for idx in shard.data.row_range(dd) {
            let wi = shard.data.col[idx] as usize;
            shard.mu[idx * k..(idx + 1) * k]
                .copy_from_slice(&mu_in[(dd * w + wi) * k..(dd * w + wi + 1) * k]);
        }
    }
    shard.recompute_stats();

    // N=1 global phi = phi_prev + own gradient (same as ref.sweep_ref)
    let mut phi = phi_prev.clone();
    for (p, &gr) in phi.iter_mut().zip(&shard.dphi) {
        *p += gr;
    }
    let mut phi_tot = vec![0f32; k];
    for row in phi.chunks_exact(k) {
        for (t, &v) in row.iter().enumerate() {
            phi_tot[t] += v;
        }
    }
    let sel = Selection::full(w);
    shard.clear_selected_residuals(&sel);
    shard.sweep(&phi, &phi_tot, &sel, &params, true);

    let tol = 5e-4f32;
    // messages on active entries
    for dd in 0..d {
        for idx in shard.data.row_range(dd) {
            let wi = shard.data.col[idx] as usize;
            for t in 0..k {
                let got = shard.mu[idx * k + t];
                let want = want_mu[(dd * w + wi) * k + t];
                assert!(
                    (got - want).abs() <= tol * want.abs().max(1.0),
                    "mu[{dd},{wi},{t}] {got} vs {want}"
                );
            }
        }
    }
    for (i, (&got, &want)) in shard.theta.iter().zip(&want_theta).enumerate() {
        assert!((got - want).abs() <= tol * want.abs().max(1.0), "theta[{i}] {got} vs {want}");
    }
    for (i, (&got, &want)) in shard.dphi.iter().zip(&want_dphi).enumerate() {
        assert!((got - want).abs() <= tol * want.abs().max(1.0), "dphi[{i}] {got} vs {want}");
    }
    for (i, (&got, &want)) in shard.r.iter().zip(&want_r).enumerate() {
        assert!((got - want).abs() <= 1e-3 * want.abs().max(1.0), "r[{i}] {got} vs {want}");
    }
}
