//! Equivalence and drift tests for the owner-sliced reduce-scatter
//! (comm::allreduce) and the coordinator's overlap pipeline:
//!
//! * a seeded multi-iteration run through the owner-sliced fused step,
//!   the **slice-granular** pipelined step, the retained per-worker
//!   rounds pipeline and the retired leader-pool step — the 5-way
//!   equivalence — must all match the pre-refactor serial leader loop
//!   bitwise on `phi_eff`/`r_global`, for full and power schedules, for
//!   N ∈ {1, 2, 4}, at OS-thread budgets {1, 2, 8};
//! * the fused and both pipelined paths must agree on the f64-backed
//!   totals bitwise (the coordinator's overlap mode depends on it);
//! * an overlapped coordinator run must be bitwise identical to the
//!   serialized run — model, per-iteration residuals — at every thread
//!   budget, while its ledger hides `Σ min(compute, comm)` plus the
//!   deferred end-of-batch fold comm;
//! * the f64-backed totals must not drift from a from-scratch recompute
//!   over hundreds of sparse scatters.

use std::sync::Mutex;

use pobp::comm::allreduce::{
    allreduce_step, allreduce_step_overlap, allreduce_step_overlap_rounds,
    allreduce_step_pool, serial_reference_step, GlobalState, ReducePlan, ReduceSource,
    SerialState, SyncScratch,
};
use pobp::comm::Cluster;
use pobp::coordinator::{fit, PobpConfig};
use pobp::corpus::shard_ranges;
use pobp::engine::bp::{Selection, ShardBp};
use pobp::engine::traits::LdaParams;
use pobp::sched::{select_power, PowerParams};
use pobp::synth::{generate, SynthSpec};
use pobp::util::rng::Rng;

/// Run `iters` sweep+sync rounds on a seeded corpus, applying the
/// owner-sliced, pipelined, leader-pool and serial reductions to the
/// same worker state each round, and assert bitwise equality of the
/// replicated matrices (and, between the fused and pipelined owner
/// paths, of the f64 totals).
fn equiv_case(n: usize, threads: usize, power: Option<PowerParams>, seed: u64) {
    let corpus = generate(&SynthSpec::tiny(seed)).corpus;
    let k = 8;
    let w = corpus.w;
    let params = LdaParams::paper(k);
    let cluster = Cluster::new(n, threads);
    let mut rng = Rng::new(seed);

    let ranges = shard_ranges(corpus.docs(), n);
    let shards: Vec<Mutex<ShardBp>> = ranges
        .iter()
        .enumerate()
        .map(|(i, rg)| {
            let mut wrng = rng.split(i as u64);
            Mutex::new(ShardBp::init(corpus.slice_docs(rg.start, rg.end), k, &mut wrng))
        })
        .collect();

    // non-trivial accumulated model so the φ̂_acc seeding path is covered
    let phi_acc: Vec<f32> = (0..w * k).map(|_| rng.f32() * 0.1).collect();
    let mut own = GlobalState::new(&phi_acc, k);
    let mut pipe = GlobalState::new(&phi_acc, k);
    let mut rounds = GlobalState::new(&phi_acc, k);
    let mut pool = GlobalState::new(&phi_acc, k);
    let mut ser = SerialState::new(&phi_acc, k);
    let mut scr_own = SyncScratch::default();
    let mut scr_pipe = SyncScratch::default();
    let mut scr_rounds = SyncScratch::default();
    let mut selection = Selection::full(w);
    let mut flat: Option<Vec<u32>> = None;

    for t in 0..8 {
        // sweep every shard against the owner-sliced path's state
        let phi = own.phi_eff.clone();
        let tot = own.phi_tot().to_vec();
        for s in &shards {
            let mut g = s.lock().unwrap();
            g.clear_selected_residuals(&selection);
            g.sweep(&phi, &tot, &selection, &params, true);
        }

        let plan = match &flat {
            None => ReducePlan::Dense { len: w * k },
            Some(ix) => ReducePlan::Subset { indices: ix },
        };
        let pairs = allreduce_step(&cluster, &plan, &phi_acc, &shards, &mut own, &mut scr_own);
        allreduce_step_overlap(&cluster, &plan, &phi_acc, &shards, &mut pipe, &mut scr_pipe);
        allreduce_step_overlap_rounds(
            &cluster, &plan, &phi_acc, &shards, &mut rounds, &mut scr_rounds,
        );
        allreduce_step_pool(&cluster, &plan, &phi_acc, &shards, &mut pool);
        serial_reference_step(&plan, k, &phi_acc, &shards, &mut ser);
        assert!(pairs > 0);
        let ctx = format!("t={t}, n={n}, threads={threads}");
        assert_eq!(own.phi_eff, ser.phi_eff, "owner-sliced phi_eff diverged at {ctx}");
        assert_eq!(own.r_global, ser.r_global, "owner-sliced r diverged at {ctx}");
        assert_eq!(pipe.phi_eff, ser.phi_eff, "slice-granular phi_eff diverged at {ctx}");
        assert_eq!(pipe.r_global, ser.r_global, "slice-granular r diverged at {ctx}");
        assert_eq!(rounds.phi_eff, ser.phi_eff, "rounds phi_eff diverged at {ctx}");
        assert_eq!(rounds.r_global, ser.r_global, "rounds r diverged at {ctx}");
        assert_eq!(pool.phi_eff, ser.phi_eff, "leader-pool phi_eff diverged at {ctx}");
        assert_eq!(pool.r_global, ser.r_global, "leader-pool r diverged at {ctx}");
        // fused vs both pipelines: identical f64 totals sequence — the
        // overlap-mode bitwise-equivalence contract
        assert_eq!(own.phi_tot(), pipe.phi_tot(), "{ctx}");
        assert_eq!(own.r_total().to_bits(), pipe.r_total().to_bits(), "{ctx}");
        assert_eq!(own.phi_tot(), rounds.phi_tot(), "{ctx}");
        assert_eq!(own.r_total().to_bits(), rounds.r_total().to_bits(), "{ctx}");

        if let Some(pp) = &power {
            let ps = select_power(&own.r_global, w, k, pp);
            flat = Some(ps.flat_indices(k));
            selection = Selection::from_power(&ps, w);
        }
    }
}

#[test]
fn parallel_matches_serial_full_n1() {
    equiv_case(1, 0, None, 11);
}

#[test]
fn parallel_matches_serial_full_n2() {
    equiv_case(2, 0, None, 12);
}

#[test]
fn parallel_matches_serial_full_n4() {
    equiv_case(4, 0, None, 13);
}

#[test]
fn parallel_matches_serial_power_n1() {
    equiv_case(1, 0, Some(PowerParams { lambda_w: 0.15, lambda_k_times_k: 4 }), 21);
}

#[test]
fn parallel_matches_serial_power_n2() {
    equiv_case(2, 0, Some(PowerParams { lambda_w: 0.15, lambda_k_times_k: 4 }), 22);
}

#[test]
fn parallel_matches_serial_power_n4() {
    equiv_case(4, 0, Some(PowerParams { lambda_w: 0.15, lambda_k_times_k: 4 }), 23);
}

/// The acceptance sweep: dense and subset plans at pinned OS-thread
/// budgets — the owner partition derives from the logical worker count
/// only, so every budget must produce the same bits.
#[test]
fn parallel_matches_serial_all_thread_budgets() {
    for &threads in &[1usize, 2, 8] {
        equiv_case(3, threads, None, 31);
        equiv_case(3, threads, Some(PowerParams { lambda_w: 0.2, lambda_k_times_k: 3 }), 32);
    }
}

/// Coordinator-level pin: an overlapped run (pipelined allreduce,
/// prefetched shard construction, max(compute, comm) accounting) is
/// bitwise identical to the serialized run at thread budgets 1/2/8 —
/// model bits, per-iteration residuals, synced pair counts — while the
/// ledger actually hides communication and keeps bytes exact.
#[test]
fn overlapped_coordinator_bitwise_equals_serialized() {
    let corpus = generate(&SynthSpec::tiny(31)).corpus;
    let params = LdaParams::paper(8);
    let base = PobpConfig {
        n_workers: 3,
        nnz_budget: 900,
        max_iters: 8,
        converge_thresh: 0.0, // pin the iteration count
        ..Default::default()
    };
    let ser = fit(&corpus, &params, &PobpConfig { overlap: false, ..base.clone() });
    assert_eq!(ser.ledger.overlap_saved_secs, 0.0);
    for threads in [1usize, 2, 8] {
        let ov = fit(
            &corpus,
            &params,
            &PobpConfig { overlap: true, max_threads: threads, ..base.clone() },
        );
        assert_eq!(ov.model.phi_wk, ser.model.phi_wk, "threads={threads}");
        assert_eq!(ov.history.len(), ser.history.len(), "threads={threads}");
        for (a, b) in ov.history.iter().zip(&ser.history) {
            assert_eq!(
                a.residual_per_token.to_bits(),
                b.residual_per_token.to_bits(),
                "batch {} iter {} residual diverged at threads={threads}",
                a.batch,
                a.iter
            );
            assert_eq!(a.synced_pairs, b.synced_pairs);
        }
        // ledger: totals follow the overlap semantics
        // (total = Σ_iters max(compute, comm) + serialized folds), with
        // byte counts and sync schedule identical to the serialized run
        let l = &ov.ledger;
        assert!(l.overlap_saved_secs > 0.0, "threads={threads}: nothing hidden");
        assert!(l.total_secs() < l.compute_secs + l.comm_secs);
        assert!(l.total_secs() + 1e-12 >= l.compute_secs.max(l.comm_secs));
        assert_eq!(l.payload_bytes_total(), ser.ledger.payload_bytes_total());
        assert_eq!(l.sync_count(), ser.ledger.sync_count());
        assert_eq!(l.wire_bytes, ser.ledger.wire_bytes);
    }
}

struct VecSource {
    dphi: Vec<f32>,
    r: Vec<f32>,
}

impl ReduceSource for VecSource {
    fn dense_parts(&self) -> (&[f32], &[f32]) {
        (&self.dphi, &self.r)
    }
}

/// Long-run drift: hundreds of sparse scatters with mutating partials.
/// The f64-backed running totals (now accumulated per owner slice and
/// merged in owner order) must stay within f64-rounding distance of a
/// from-scratch recompute — the old f32 incremental bookkeeping drifted
/// orders of magnitude more over the same schedule.
#[test]
fn subset_totals_do_not_drift_over_long_runs() {
    let (w, k) = (300, 16);
    let mut rng = Rng::new(7);
    let phi_acc: Vec<f32> = (0..w * k).map(|_| rng.f32() * 10.0).collect();
    let cluster = Cluster::new(3, 0);
    let workers: Vec<Mutex<VecSource>> = (0..3)
        .map(|_| {
            Mutex::new(VecSource {
                dphi: (0..w * k).map(|_| rng.f32() * 5.0).collect(),
                r: (0..w * k).map(|_| rng.f32()).collect(),
            })
        })
        .collect();

    let mut st = GlobalState::new(&phi_acc, k);
    let mut scratch = SyncScratch::default();
    for round in 0..400 {
        for m in &workers {
            let mut g = m.lock().unwrap();
            for v in g.dphi.iter_mut() {
                *v += rng.f32() - 0.5;
            }
            for v in g.r.iter_mut() {
                *v = rng.f32();
            }
        }
        let mut indices: Vec<u32> =
            (0..(w * k) as u32).filter(|_| rng.f32() < 0.05).collect();
        if indices.is_empty() {
            indices.push(rng.below(w * k) as u32);
        }
        let plan = ReducePlan::Subset { indices: &indices };
        // rotate through the fused, slice-granular and rounds steps: all
        // three must keep the same running totals
        match round % 3 {
            0 => {
                allreduce_step(&cluster, &plan, &phi_acc, &workers, &mut st, &mut scratch);
            }
            1 => {
                allreduce_step_overlap(
                    &cluster, &plan, &phi_acc, &workers, &mut st, &mut scratch,
                );
            }
            _ => {
                allreduce_step_overlap_rounds(
                    &cluster, &plan, &phi_acc, &workers, &mut st, &mut scratch,
                );
            }
        }

        let (phi_drift, r_drift) = st.totals_drift();
        assert!(
            phi_drift < 1e-4,
            "phi_tot drifted {phi_drift} at round {round}"
        );
        assert!(r_drift < 1e-4, "r_total drifted {r_drift} at round {round}");
    }
}
