//! Equivalence and drift tests for the parallel sparse allreduce
//! (comm::allreduce): a seeded multi-iteration run through the chunked
//! parallel reduction must match the pre-refactor serial leader loop
//! bitwise on `phi_eff`/`r_global`, for full and power schedules and for
//! N ∈ {1, 2, 4}; and the f64-backed totals must not drift from a
//! from-scratch recompute over hundreds of sparse scatters.

use std::sync::Mutex;

use pobp::comm::allreduce::{
    allreduce_step, serial_reference_step, GlobalState, ReducePlan, ReduceSource,
    SerialState,
};
use pobp::comm::Cluster;
use pobp::corpus::shard_ranges;
use pobp::engine::bp::{Selection, ShardBp};
use pobp::engine::traits::LdaParams;
use pobp::sched::{select_power, PowerParams};
use pobp::synth::{generate, SynthSpec};
use pobp::util::rng::Rng;

/// Run `iters` sweep+sync rounds on a seeded corpus, applying the
/// parallel and the serial reduction to the same worker state each
/// round, and assert bitwise equality of the replicated matrices.
fn equiv_case(n: usize, power: Option<PowerParams>, seed: u64) {
    let corpus = generate(&SynthSpec::tiny(seed)).corpus;
    let k = 8;
    let w = corpus.w;
    let params = LdaParams::paper(k);
    let cluster = Cluster::new(n, 0);
    let mut rng = Rng::new(seed);

    let ranges = shard_ranges(corpus.docs(), n);
    let shards: Vec<Mutex<ShardBp>> = ranges
        .iter()
        .enumerate()
        .map(|(i, rg)| {
            let mut wrng = rng.split(i as u64);
            Mutex::new(ShardBp::init(corpus.slice_docs(rg.start, rg.end), k, &mut wrng))
        })
        .collect();

    // non-trivial accumulated model so the φ̂_acc seeding path is covered
    let phi_acc: Vec<f32> = (0..w * k).map(|_| rng.f32() * 0.1).collect();
    let mut par = GlobalState::new(&phi_acc, k);
    let mut ser = SerialState::new(&phi_acc, k);
    let mut selection = Selection::full(w);
    let mut flat: Option<Vec<u32>> = None;

    for t in 0..8 {
        // sweep every shard against the parallel path's state
        let phi = par.phi_eff.clone();
        let tot = par.phi_tot().to_vec();
        for s in &shards {
            let mut g = s.lock().unwrap();
            g.clear_selected_residuals(&selection);
            g.sweep(&phi, &tot, &selection, &params, true);
        }

        let plan = match &flat {
            None => ReducePlan::Dense { len: w * k },
            Some(ix) => ReducePlan::Subset { indices: ix },
        };
        let pairs = allreduce_step(&cluster, &plan, &phi_acc, &shards, &mut par);
        serial_reference_step(&plan, k, &phi_acc, &shards, &mut ser);
        assert!(pairs > 0);
        assert_eq!(par.phi_eff, ser.phi_eff, "phi_eff diverged at t={t}, n={n}");
        assert_eq!(par.r_global, ser.r_global, "r diverged at t={t}, n={n}");

        if let Some(pp) = &power {
            let ps = select_power(&par.r_global, w, k, pp);
            flat = Some(ps.flat_indices(k));
            selection = Selection::from_power(&ps, w);
        }
    }
}

#[test]
fn parallel_matches_serial_full_n1() {
    equiv_case(1, None, 11);
}

#[test]
fn parallel_matches_serial_full_n2() {
    equiv_case(2, None, 12);
}

#[test]
fn parallel_matches_serial_full_n4() {
    equiv_case(4, None, 13);
}

#[test]
fn parallel_matches_serial_power_n1() {
    equiv_case(1, Some(PowerParams { lambda_w: 0.15, lambda_k_times_k: 4 }), 21);
}

#[test]
fn parallel_matches_serial_power_n2() {
    equiv_case(2, Some(PowerParams { lambda_w: 0.15, lambda_k_times_k: 4 }), 22);
}

#[test]
fn parallel_matches_serial_power_n4() {
    equiv_case(4, Some(PowerParams { lambda_w: 0.15, lambda_k_times_k: 4 }), 23);
}

struct VecSource {
    dphi: Vec<f32>,
    r: Vec<f32>,
}

impl ReduceSource for VecSource {
    fn dense_parts(&self) -> (&[f32], &[f32]) {
        (&self.dphi, &self.r)
    }
}

/// Long-run drift: hundreds of sparse scatters with mutating partials.
/// The f64-backed running totals must stay within f64-rounding distance
/// of a from-scratch recompute — the old f32 incremental bookkeeping
/// drifted orders of magnitude more over the same schedule.
#[test]
fn subset_totals_do_not_drift_over_long_runs() {
    let (w, k) = (300, 16);
    let mut rng = Rng::new(7);
    let phi_acc: Vec<f32> = (0..w * k).map(|_| rng.f32() * 10.0).collect();
    let cluster = Cluster::new(3, 0);
    let workers: Vec<Mutex<VecSource>> = (0..3)
        .map(|_| {
            Mutex::new(VecSource {
                dphi: (0..w * k).map(|_| rng.f32() * 5.0).collect(),
                r: (0..w * k).map(|_| rng.f32()).collect(),
            })
        })
        .collect();

    let mut st = GlobalState::new(&phi_acc, k);
    for round in 0..400 {
        for m in &workers {
            let mut g = m.lock().unwrap();
            for v in g.dphi.iter_mut() {
                *v += rng.f32() - 0.5;
            }
            for v in g.r.iter_mut() {
                *v = rng.f32();
            }
        }
        let mut indices: Vec<u32> =
            (0..(w * k) as u32).filter(|_| rng.f32() < 0.05).collect();
        if indices.is_empty() {
            indices.push(rng.below(w * k) as u32);
        }
        let plan = ReducePlan::Subset { indices: &indices };
        allreduce_step(&cluster, &plan, &phi_acc, &workers, &mut st);

        let (phi_drift, r_drift) = st.totals_drift();
        assert!(
            phi_drift < 1e-4,
            "phi_tot drifted {phi_drift} at round {round}"
        );
        assert!(r_drift < 1e-4, "r_total drifted {r_drift} at round {round}");
    }
}
