//! Equivalence and determinism tests for the doc-parallel sweep engine
//! (engine::bp): the fused serial kernel must match the pre-fusion
//! reference sweep bitwise; the doc-parallel sweep must match it exactly
//! on μ/θ̂/residual (documents own their rows; per-doc f64 partials are
//! summed in doc order), within tight tolerances on the block-merged
//! Δφ̂/r, bitwise on frozen un-selected pairs, and bitwise-reproducibly
//! across thread budgets {1, 2, 8} and repeated runs.

use pobp::comm::Cluster;
use pobp::engine::bp::{Selection, ShardBp};
use pobp::engine::traits::LdaParams;
use pobp::sched::{select_power, DocSchedule, PowerParams};
use pobp::synth::{generate, SynthSpec};
use pobp::util::partial_sort::top_k_desc;
use pobp::util::rng::Rng;

const K: usize = 8;

/// Fresh shard from a pinned seed: two calls give bitwise-identical
/// state. Sized well past the block-partition threshold so the parallel
/// engine genuinely runs multiple doc blocks.
fn fresh_shard(seed: u64) -> ShardBp {
    let spec = SynthSpec { docs: 400, ..SynthSpec::tiny(seed) };
    let corpus = generate(&spec).corpus;
    let mut rng = Rng::new(seed);
    ShardBp::init(corpus, K, &mut rng)
}

fn phi_of(shard: &ShardBp) -> (Vec<f32>, Vec<f32>) {
    let phi = shard.dphi.clone();
    let mut tot = vec![0f32; shard.k];
    for row in phi.chunks_exact(shard.k) {
        for (t, &v) in row.iter().enumerate() {
            tot[t] += v;
        }
    }
    (phi, tot)
}

/// Copy the synchronizable state of `src` into `dst` (same corpus/seed
/// required). θ̂_old needs no copy: every sweep re-snapshots it.
fn resync(dst: &mut ShardBp, src: &ShardBp) {
    dst.mu.copy_from_slice(&src.mu);
    dst.theta.copy_from_slice(&src.theta);
    dst.dphi.copy_from_slice(&src.dphi);
    dst.r.copy_from_slice(&src.r);
}

fn assert_bitwise(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(x == y, "{what}[{i}]: {x} vs {y} (bitwise)");
    }
}

/// |a - b| ≤ tol · max(|a|, |b|, 1) per element — the merge-association
/// bound for the block-summed Δφ̂/r matrices.
fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let scale = x.abs().max(y.abs()).max(1.0);
        assert!(
            (x - y).abs() <= tol * scale,
            "{what}[{i}]: {x} vs {y} (tol {tol})"
        );
    }
}

fn mass(v: &[f32]) -> f64 {
    v.iter().map(|&x| x as f64).sum()
}

/// A non-trivial power selection derived from a warmed-up shard.
fn warmed_selection(shard: &mut ShardBp, p: &LdaParams) -> Selection {
    let sel_f = Selection::full(shard.data.w);
    let (phi, tot) = phi_of(shard);
    shard.clear_selected_residuals(&sel_f);
    shard.sweep(&phi, &tot, &sel_f, p, true);
    let ps = select_power(
        &shard.r,
        shard.data.w,
        shard.k,
        &PowerParams { lambda_w: 0.2, lambda_k_times_k: 3 },
    );
    Selection::from_power(&ps, shard.data.w)
}

#[test]
fn fused_serial_matches_reference_bitwise() {
    let p = LdaParams::paper(K);
    // full then power selection, multi-iteration: the fused kernel must
    // reproduce the pre-fusion reference kernel bit-for-bit
    let mut a = fresh_shard(31); // reference
    let mut b = fresh_shard(31); // fused
    let w = a.data.w;
    let mut sel = Selection::full(w);
    for round in 0..4 {
        let (phi, tot) = phi_of(&a);
        a.clear_selected_residuals(&sel);
        let ra = a.sweep_reference(&phi, &tot, &sel, &p, true);
        b.clear_selected_residuals(&sel);
        let rb = b.sweep(&phi, &tot, &sel, &p, true);
        assert!(ra == rb, "round {round}: residual {ra} vs {rb}");
        assert_bitwise(&a.mu, &b.mu, "mu");
        assert_bitwise(&a.theta, &b.theta, "theta");
        assert_bitwise(&a.dphi, &b.dphi, "dphi");
        assert_bitwise(&a.r, &b.r, "r");
        let ps = select_power(
            &a.r, w, K,
            &PowerParams { lambda_w: 0.25, lambda_k_times_k: 4 },
        );
        sel = Selection::from_power(&ps, w);
    }
}

#[test]
fn inverted_sweep_matches_fused_doc_order_bitwise() {
    // same entries, same per-row accumulation order — only the f64
    // residual total associates differently
    let p = LdaParams::paper(K);
    let mut a = fresh_shard(37);
    let mut b = fresh_shard(37);
    let sel = warmed_selection(&mut a, &p);
    {
        let (phi, tot) = phi_of(&b);
        let sel_f = Selection::full(b.data.w);
        b.clear_selected_residuals(&sel_f);
        b.sweep(&phi, &tot, &sel_f, &p, true);
    }
    let (phi, tot) = phi_of(&a);
    a.clear_selected_residuals(&sel);
    let ra = a.sweep(&phi, &tot, &sel, &p, true);
    b.clear_selected_residuals(&sel);
    let rb = b.sweep_selected(&phi, &tot, &sel, &p, true);
    assert_bitwise(&a.mu, &b.mu, "mu");
    assert_bitwise(&a.theta, &b.theta, "theta");
    assert_bitwise(&a.dphi, &b.dphi, "dphi");
    assert_bitwise(&a.r, &b.r, "r");
    let scale = ra.abs().max(1.0);
    assert!((ra - rb).abs() < 1e-9 * scale, "residual {ra} vs {rb}");
}

/// Core tentpole contract: parallel vs serial at budgets {1, 2, 8}, full
/// selection, multi-iteration with resync so every round compares one
/// sweep from identical state.
#[test]
fn parallel_matches_serial_full_selection() {
    let p = LdaParams::paper(K);
    for &budget in &[1usize, 2, 8] {
        let pool = Cluster::new(1, 0);
        let mut ser = fresh_shard(41);
        let mut par = fresh_shard(41);
        let sel = Selection::full(ser.data.w);
        for round in 0..3 {
            resync(&mut par, &ser);
            let (phi, tot) = phi_of(&ser);
            ser.clear_selected_residuals(&sel);
            let rs = ser.sweep_reference(&phi, &tot, &sel, &p, true);
            let (rp, timing) =
                par.sweep_parallel(&pool, budget, &phi, &tot, &sel, &p, true);
            // documents own μ/θ̂ and the residual partials: bitwise
            assert_bitwise(&ser.mu, &par.mu, "mu");
            assert_bitwise(&ser.theta, &par.theta, "theta");
            assert!(
                rs == rp,
                "budget {budget} round {round}: residual {rs} vs {rp}"
            );
            // block-merged accumulations: association-bounded
            assert_close(&ser.dphi, &par.dphi, 2e-4, "dphi");
            assert_close(&ser.r, &par.r, 2e-4, "r");
            let (ms, mp) = (mass(&ser.dphi), mass(&par.dphi));
            assert!(
                (ms - mp).abs() <= 1e-5 * ms.abs().max(1.0),
                "dphi mass {ms} vs {mp}"
            );
            assert!(!timing.block_secs.is_empty());
            assert!(timing.block_secs.len() > 1, "want >1 doc block for a real test");
        }
    }
}

#[test]
fn parallel_matches_serial_power_selection_and_freezes_unselected() {
    let p = LdaParams::paper(K);
    for &budget in &[1usize, 2, 8] {
        let pool = Cluster::new(1, 0);
        let mut ser = fresh_shard(43);
        let sel = warmed_selection(&mut ser, &p);
        let mut par = fresh_shard(43);
        resync(&mut par, &ser);

        let mu_before = ser.mu.clone();
        let dphi_before = ser.dphi.clone();
        let r_before = ser.r.clone();

        let (phi, tot) = phi_of(&ser);
        ser.clear_selected_residuals(&sel);
        let rs = ser.sweep_reference(&phi, &tot, &sel, &p, true);
        let (rp, _) = par.sweep_parallel(&pool, budget, &phi, &tot, &sel, &p, true);

        assert_bitwise(&ser.mu, &par.mu, "mu");
        assert_bitwise(&ser.theta, &par.theta, "theta");
        assert!(rs == rp, "budget {budget}: residual {rs} vs {rp}");
        assert_close(&ser.dphi, &par.dphi, 2e-4, "dphi");
        assert_close(&ser.r, &par.r, 2e-4, "r");

        // frozen un-selected pairs: exact (acceptance contract)
        let k = par.k;
        for wi in 0..par.data.w {
            match sel.topics_of(wi) {
                Some(ts) if sel.word_sel[wi] => {
                    let selset: std::collections::HashSet<usize> =
                        ts.iter().map(|&t| t as usize).collect();
                    for t in 0..k {
                        if !selset.contains(&t) {
                            assert!(
                                par.dphi[wi * k + t] == dphi_before[wi * k + t],
                                "unselected topic moved: w{wi} t{t}"
                            );
                            assert!(
                                par.r[wi * k + t] == r_before[wi * k + t],
                                "unselected residual moved: w{wi} t{t}"
                            );
                        }
                    }
                }
                _ => {
                    for t in 0..k {
                        assert!(
                            par.dphi[wi * k + t] == dphi_before[wi * k + t],
                            "unselected word moved: w{wi} t{t}"
                        );
                        assert!(
                            par.r[wi * k + t] == r_before[wi * k + t],
                            "unselected word residual moved: w{wi} t{t}"
                        );
                    }
                }
            }
        }
        // messages of un-selected words bitwise frozen
        for d in 0..par.data.docs() {
            for idx in par.data.row_range(d) {
                let wi = par.data.col[idx] as usize;
                if !sel.word_sel[wi] {
                    assert_bitwise(
                        &par.mu[idx * k..(idx + 1) * k],
                        &mu_before[idx * k..(idx + 1) * k],
                        "frozen mu row",
                    );
                }
            }
        }
    }
}

/// The determinism contract: block boundaries come from NNZ counts, the
/// merge folds in block order, so the parallel result is bitwise
/// identical across thread budgets and across repeated runs.
#[test]
fn parallel_bitwise_reproducible_across_budgets_and_runs() {
    let p = LdaParams::paper(K);
    let run = |budget: usize| -> ShardBp {
        let pool = Cluster::new(1, 0);
        let mut s = fresh_shard(47);
        let w = s.data.w;
        let mut sel = Selection::full(w);
        for _ in 0..4 {
            let (phi, tot) = phi_of(&s);
            s.sweep_parallel(&pool, budget, &phi, &tot, &sel, &p, true);
            let ps = select_power(
                &s.r, w, K,
                &PowerParams { lambda_w: 0.3, lambda_k_times_k: 4 },
            );
            sel = Selection::from_power(&ps, w);
        }
        s
    };
    let base = run(1);
    for &budget in &[1usize, 2, 8] {
        let other = run(budget);
        assert_bitwise(&base.mu, &other.mu, "mu");
        assert_bitwise(&base.theta, &other.theta, "theta");
        assert_bitwise(&base.dphi, &other.dphi, "dphi");
        assert_bitwise(&base.r, &other.r, "r");
    }
}

/// ABP granule contract: `sweep_docs` (one context, fused kernel) returns
/// per-doc residuals and leaves state bitwise equal to the pre-fusion
/// per-doc reference loop over the same schedule.
#[test]
fn abp_doc_granule_residuals_unchanged() {
    let p = LdaParams::paper(K);
    let mut a = fresh_shard(53);
    let sel = warmed_selection(&mut a, &p);
    let mut b = fresh_shard(53);
    resync(&mut b, &a);

    let scheduled: Vec<u32> =
        (0..a.data.docs() as u32).filter(|d| d % 3 != 1).collect();
    let (phi, tot) = phi_of(&a);

    a.clear_selected_residuals(&sel);
    let mut ref_resid = Vec::with_capacity(scheduled.len());
    for &d in &scheduled {
        ref_resid.push(a.sweep_doc_reference(d as usize, &phi, &tot, &sel, &p, true));
    }

    b.clear_selected_residuals(&sel);
    let fused_resid = b.sweep_docs(&scheduled, &phi, &tot, &sel, &p, true);

    assert_eq!(ref_resid.len(), fused_resid.len());
    for (i, (x, y)) in ref_resid.iter().zip(&fused_resid).enumerate() {
        assert!(x == y, "doc {}: residual {x} vs {y}", scheduled[i]);
    }
    assert_bitwise(&a.mu, &b.mu, "mu");
    assert_bitwise(&a.theta, &b.theta, "theta");
    assert_bitwise(&a.dphi, &b.dphi, "dphi");
    assert_bitwise(&a.r, &b.r, "r");
}

/// The parallel sweep's per-doc residuals must equal the serial per-doc
/// returns (the signal ABP's t = 1 consumes without a second pass).
#[test]
fn parallel_doc_residuals_match_serial_per_doc_returns() {
    let p = LdaParams::paper(K);
    let mut ser = fresh_shard(59);
    let mut par = fresh_shard(59);
    let sel = Selection::full(ser.data.w);
    let (phi, tot) = phi_of(&ser);

    ser.clear_selected_residuals(&sel);
    let per_doc: Vec<f64> = (0..ser.data.docs())
        .map(|d| ser.sweep_doc_reference(d, &phi, &tot, &sel, &p, true))
        .collect();

    let pool = Cluster::new(1, 0);
    par.sweep_parallel(&pool, 0, &phi, &tot, &sel, &p, true);
    assert_eq!(par.doc_residuals().len(), per_doc.len());
    for (d, (x, y)) in per_doc.iter().zip(par.doc_residuals()).enumerate() {
        assert!(x == y, "doc {d}: {x} vs {y}");
    }
}

/// A residual-descending document schedule (the ABP t ≥ 2 shape) over a
/// warmed shard: top `frac` of the docs by last-sweep residual.
fn residual_schedule(shard: &ShardBp, frac: f64) -> Vec<u32> {
    let r_doc: Vec<f32> = shard.doc_residuals().iter().map(|&v| v as f32).collect();
    let n = ((frac * r_doc.len() as f64).ceil() as usize).clamp(1, r_doc.len());
    top_k_desc(&r_doc, n)
}

/// Warm a shard with one full parallel sweep (populating per-doc
/// residuals) and hand back a residual-descending schedule.
fn warmed_with_schedule(seed: u64, frac: f64) -> (ShardBp, Vec<u32>) {
    let pool = Cluster::new(1, 0);
    let mut s = fresh_shard(seed);
    let sel = Selection::full(s.data.w);
    let p = LdaParams::paper(K);
    let (phi, tot) = phi_of(&s);
    s.sweep_parallel(&pool, 0, &phi, &tot, &sel, &p, true);
    let sched = residual_schedule(&s, frac);
    (s, sched)
}

/// Tentpole contract: the scheduled-parallel sweep vs the serial
/// `sweep_docs` oracle at thread budgets {1, 2, 8} — μ/θ̂ and the per-doc
/// residuals (schedule order) bitwise, Δφ̂/r association-bounded — for
/// both the full and a power selection.
#[test]
fn scheduled_parallel_matches_serial_sweep_docs() {
    let p = LdaParams::paper(K);
    for &budget in &[1usize, 2, 8] {
        for &full_sel in &[true, false] {
            let pool = Cluster::new(1, 0);
            let (mut ser, sched) = warmed_with_schedule(67, 0.35);
            let sel = if full_sel {
                Selection::full(ser.data.w)
            } else {
                let ps = select_power(
                    &ser.r,
                    ser.data.w,
                    K,
                    &PowerParams { lambda_w: 0.2, lambda_k_times_k: 3 },
                );
                Selection::from_power(&ps, ser.data.w)
            };
            let mut par = fresh_shard(67);
            resync(&mut par, &ser);
            let (phi, tot) = phi_of(&ser);

            ser.clear_selected_residuals(&sel);
            let ser_resid = ser.sweep_docs(&sched, &phi, &tot, &sel, &p, true);

            par.clear_selected_residuals(&sel);
            let ds = DocSchedule::build(&sched, |d| par.data.row_range(d).len());
            assert!(ds.blocks() > 1, "want a multi-block schedule for a real test");
            let (par_resid, timing) =
                par.sweep_docs_parallel(&pool, budget, &ds, &phi, &tot, &sel, &p, true);

            // documents own μ/θ̂ and their residual: bitwise, and the
            // parallel residuals come back in schedule order
            assert_bitwise(&ser.mu, &par.mu, "mu");
            assert_bitwise(&ser.theta, &par.theta, "theta");
            assert_eq!(ser_resid.len(), par_resid.len());
            for (i, (x, y)) in ser_resid.iter().zip(&par_resid).enumerate() {
                assert!(
                    x == y,
                    "budget {budget} full={full_sel} doc {}: residual {x} vs {y}",
                    sched[i]
                );
            }
            // block-merged accumulations: association-bounded
            assert_close(&ser.dphi, &par.dphi, 2e-4, "dphi");
            assert_close(&ser.r, &par.r, 2e-4, "r");
            let (ms, mp) = (mass(&ser.dphi), mass(&par.dphi));
            assert!(
                (ms - mp).abs() <= 1e-5 * ms.abs().max(1.0),
                "dphi mass {ms} vs {mp}"
            );
            assert_eq!(timing.block_secs.len(), ds.blocks());
        }
    }
}

/// Un-scheduled documents and un-selected pairs stay bitwise frozen
/// under the scheduled-parallel sweep.
#[test]
fn scheduled_parallel_freezes_unscheduled_and_unselected() {
    let p = LdaParams::paper(K);
    let pool = Cluster::new(1, 0);
    let (mut s, sched) = warmed_with_schedule(71, 0.25);
    let ps = select_power(
        &s.r,
        s.data.w,
        K,
        &PowerParams { lambda_w: 0.3, lambda_k_times_k: 4 },
    );
    let sel = Selection::from_power(&ps, s.data.w);
    let in_sched: std::collections::HashSet<u32> = sched.iter().copied().collect();
    let mu_before = s.mu.clone();
    let theta_before = s.theta.clone();
    let dphi_before = s.dphi.clone();
    let r_before = s.r.clone();

    let (phi, tot) = phi_of(&s);
    s.clear_selected_residuals(&sel);
    let r_cleared = s.r.clone();
    let ds = DocSchedule::build(&sched, |d| s.data.row_range(d).len());
    s.sweep_docs_parallel(&pool, 0, &ds, &phi, &tot, &sel, &p, true);

    let k = s.k;
    // θ̂ and μ of un-scheduled docs: bitwise frozen
    for d in 0..s.data.docs() {
        if in_sched.contains(&(d as u32)) {
            continue;
        }
        assert_bitwise(
            &s.theta[d * k..(d + 1) * k],
            &theta_before[d * k..(d + 1) * k],
            "frozen theta row",
        );
        for idx in s.data.row_range(d) {
            assert_bitwise(
                &s.mu[idx * k..(idx + 1) * k],
                &mu_before[idx * k..(idx + 1) * k],
                "frozen mu row",
            );
        }
    }
    // un-selected pairs: Δφ̂ frozen at the pre-sweep value, r frozen at
    // the post-clear value (clearing touches only selected lanes)
    for wi in 0..s.data.w {
        for t in 0..k {
            let selected = sel.word_sel[wi]
                && match sel.topics_of(wi) {
                    None => true,
                    Some(ts) => ts.contains(&(t as u32)),
                };
            if !selected {
                assert!(
                    s.dphi[wi * k + t] == dphi_before[wi * k + t],
                    "unselected dphi moved: w{wi} t{t}"
                );
                assert!(
                    s.r[wi * k + t] == r_before[wi * k + t],
                    "unselected r moved: w{wi} t{t}"
                );
            } else {
                // selected pairs start from the cleared value...
                assert_eq!(r_cleared[wi * k + t], 0.0);
            }
        }
    }
}

/// Determinism: the scheduled-parallel result is bitwise identical
/// across thread budgets and repeated runs (blocks and merge order are
/// pure functions of the schedule and the data).
#[test]
fn scheduled_parallel_bitwise_reproducible_across_budgets() {
    let p = LdaParams::paper(K);
    let run = |budget: usize| -> ShardBp {
        let pool = Cluster::new(1, 0);
        let (mut s, _) = warmed_with_schedule(73, 0.4);
        let w = s.data.w;
        // several scheduled iterations, schedule re-derived from the
        // evolving per-doc residual table like ABP's loop
        let mut r_doc: Vec<f32> =
            s.doc_residuals().iter().map(|&v| v as f32).collect();
        let active = ((0.4 * r_doc.len() as f64).ceil() as usize).max(1);
        let mut sel = Selection::full(w);
        for _ in 0..3 {
            let sched = top_k_desc(&r_doc, active);
            let (phi, tot) = phi_of(&s);
            s.clear_selected_residuals(&sel);
            let ds = DocSchedule::build(&sched, |d| s.data.row_range(d).len());
            let (rds, _) =
                s.sweep_docs_parallel(&pool, budget, &ds, &phi, &tot, &sel, &p, true);
            for (&d, &rd) in sched.iter().zip(&rds) {
                r_doc[d as usize] = rd as f32;
            }
            let ps = select_power(
                &s.r, w, K,
                &PowerParams { lambda_w: 0.3, lambda_k_times_k: 4 },
            );
            sel = Selection::from_power(&ps, w);
        }
        s
    };
    let base = run(1);
    for &budget in &[1usize, 2, 8] {
        let other = run(budget);
        assert_bitwise(&base.mu, &other.mu, "mu");
        assert_bitwise(&base.theta, &other.theta, "theta");
        assert_bitwise(&base.dphi, &other.dphi, "dphi");
        assert_bitwise(&base.r, &other.r, "r");
    }
}

/// The schedule permutation never splits a document across blocks, and
/// the per-block doc lists partition the sorted schedule exactly.
#[test]
fn doc_schedule_blocks_are_doc_granular() {
    let (s, sched) = warmed_with_schedule(79, 0.5);
    let ds = DocSchedule::build(&sched, |d| s.data.row_range(d).len());
    let mut seen = std::collections::HashSet::new();
    let mut covered = 0usize;
    for b in 0..ds.blocks() {
        let docs = ds.block(b);
        assert!(!docs.is_empty(), "empty block {b}");
        for pair in docs.windows(2) {
            assert!(pair[0] < pair[1], "block {b} not ascending");
        }
        for &d in docs {
            assert!(seen.insert(d), "doc {d} appears in two blocks");
        }
        covered += docs.len();
    }
    assert_eq!(covered, sched.len());
    assert_eq!(seen.len(), sched.len());
    assert_eq!(
        ds.nnz(),
        sched.iter().map(|&d| s.data.row_range(d as usize).len()).sum::<usize>()
    );
}

/// update_phi = false must freeze Δφ̂ on the scheduled-parallel path too.
#[test]
fn scheduled_parallel_update_phi_false_freezes_gradient() {
    let p = LdaParams::paper(K);
    let pool = Cluster::new(1, 0);
    let (mut s, sched) = warmed_with_schedule(83, 0.3);
    let sel = Selection::full(s.data.w);
    let (phi, tot) = phi_of(&s);
    let dphi_before = s.dphi.clone();
    s.clear_selected_residuals(&sel);
    let ds = DocSchedule::build(&sched, |d| s.data.row_range(d).len());
    s.sweep_docs_parallel(&pool, 0, &ds, &phi, &tot, &sel, &p, false);
    assert_bitwise(&s.dphi, &dphi_before, "dphi");
}

/// Fixed-block reuse path (the high-coverage ABP fast path): the sweep
/// over the init-time block tables vs the serial `sweep_docs` oracle at
/// budgets {1, 2, 8} — μ/θ̂ and the per-doc residuals (schedule order)
/// bitwise, Δφ̂/r association-bounded — for full and power selections,
/// plus bitwise reproducibility across budgets and the unscheduled-doc
/// freeze.
#[test]
fn fixed_block_reuse_matches_serial_sweep_docs() {
    let p = LdaParams::paper(K);
    for &budget in &[1usize, 2, 8] {
        for &full_sel in &[true, false] {
            let pool = Cluster::new(1, 0);
            // high coverage — the regime the reuse path is gated to
            let (mut ser, sched) = warmed_with_schedule(89, 0.9);
            let sel = if full_sel {
                Selection::full(ser.data.w)
            } else {
                let ps = select_power(
                    &ser.r,
                    ser.data.w,
                    K,
                    &PowerParams { lambda_w: 0.2, lambda_k_times_k: 3 },
                );
                Selection::from_power(&ps, ser.data.w)
            };
            let mut par = fresh_shard(89);
            resync(&mut par, &ser);
            let (phi, tot) = phi_of(&ser);

            ser.clear_selected_residuals(&sel);
            let ser_resid = ser.sweep_docs(&sched, &phi, &tot, &sel, &p, true);

            par.clear_selected_residuals(&sel);
            let ds = DocSchedule::build(&sched, |d| par.data.row_range(d).len());
            let (par_resid, timing) = par.sweep_docs_parallel_fixed(
                &pool, budget, &ds, &phi, &tot, &sel, &p, true,
            );

            assert_bitwise(&ser.mu, &par.mu, "mu");
            assert_bitwise(&ser.theta, &par.theta, "theta");
            assert_eq!(ser_resid.len(), par_resid.len());
            for (i, (x, y)) in ser_resid.iter().zip(&par_resid).enumerate() {
                assert!(
                    x == y,
                    "budget {budget} full={full_sel} doc {}: residual {x} vs {y}",
                    sched[i]
                );
            }
            assert_close(&ser.dphi, &par.dphi, 2e-4, "dphi");
            assert_close(&ser.r, &par.r, 2e-4, "r");
            let (ms, mp) = (mass(&ser.dphi), mass(&par.dphi));
            assert!(
                (ms - mp).abs() <= 1e-5 * ms.abs().max(1.0),
                "dphi mass {ms} vs {mp}"
            );
            assert!(!timing.block_secs.is_empty());
        }
    }
}

/// The fixed-block reuse path is bitwise reproducible across thread
/// budgets (the fixed partition and the liveness-filtered merge order
/// are pure functions of the schedule and the data), and leaves
/// unscheduled documents bitwise frozen even at partial coverage.
#[test]
fn fixed_block_reuse_deterministic_and_freezes_unscheduled() {
    let p = LdaParams::paper(K);
    let run = |budget: usize| -> ShardBp {
        let pool = Cluster::new(1, 0);
        let (mut s, sched) = warmed_with_schedule(97, 0.6);
        let sel = Selection::full(s.data.w);
        let (phi, tot) = phi_of(&s);
        s.clear_selected_residuals(&sel);
        let ds = DocSchedule::build(&sched, |d| s.data.row_range(d).len());
        s.sweep_docs_parallel_fixed(&pool, budget, &ds, &phi, &tot, &sel, &p, true);
        s
    };
    let base = run(1);
    for &budget in &[2usize, 8] {
        let other = run(budget);
        assert_bitwise(&base.mu, &other.mu, "mu");
        assert_bitwise(&base.theta, &other.theta, "theta");
        assert_bitwise(&base.dphi, &other.dphi, "dphi");
        assert_bitwise(&base.r, &other.r, "r");
    }

    // freeze contract at 60% coverage: unscheduled docs untouched
    let pool = Cluster::new(1, 0);
    let (mut s, sched) = warmed_with_schedule(97, 0.6);
    let sel = Selection::full(s.data.w);
    let in_sched: std::collections::HashSet<u32> = sched.iter().copied().collect();
    let mu_before = s.mu.clone();
    let theta_before = s.theta.clone();
    let (phi, tot) = phi_of(&s);
    s.clear_selected_residuals(&sel);
    let ds = DocSchedule::build(&sched, |d| s.data.row_range(d).len());
    s.sweep_docs_parallel_fixed(&pool, 0, &ds, &phi, &tot, &sel, &p, true);
    let k = s.k;
    for d in 0..s.data.docs() {
        if in_sched.contains(&(d as u32)) {
            continue;
        }
        assert_bitwise(
            &s.theta[d * k..(d + 1) * k],
            &theta_before[d * k..(d + 1) * k],
            "frozen theta row (fixed path)",
        );
        for idx in s.data.row_range(d) {
            assert_bitwise(
                &s.mu[idx * k..(idx + 1) * k],
                &mu_before[idx * k..(idx + 1) * k],
                "frozen mu row (fixed path)",
            );
        }
    }
}

/// update_phi = false must freeze Δφ̂ on the parallel path too (the
/// heldout fold-in contract).
#[test]
fn parallel_update_phi_false_freezes_gradient() {
    let p = LdaParams::paper(K);
    let mut s = fresh_shard(61);
    let sel = Selection::full(s.data.w);
    let (phi, tot) = phi_of(&s);
    let dphi_before = s.dphi.clone();
    let pool = Cluster::new(1, 0);
    s.sweep_parallel(&pool, 0, &phi, &tot, &sel, &p, false);
    assert_bitwise(&s.dphi, &dphi_before, "dphi");
}
