//! Contract 7 (kernel lanes): the explicit-SIMD `fused_update` behind
//! `--features simd` must be **bitwise** indistinguishable from the
//! scalar oracle kernel — μ/θ̂ lanes, per-doc residuals, and (because the
//! block partition and merge order are kernel-independent) the whole
//! merged Δφ̂/r state at every thread budget.
//!
//! Every test here forces one kernel per run via
//! `simd::force_kernel` and compares against the other. Without the
//! `simd` feature the forced "wide" kernel resolves to scalar, so the
//! suite degenerates to scalar-vs-scalar and stays green — the CI
//! `--features simd` leg is where the comparison is real.
//!
//! K is deliberately **not** a multiple of the 4-float SIMD width (7 and
//! 13) so the vector main loop and the scalar tail are both exercised,
//! and the packed-gather tests use a per-word topic budget of 3 so the
//! subset path runs entirely in tail lanes on some words.

use pobp::comm::Cluster;
use pobp::engine::bp::{Selection, ShardBp};
use pobp::engine::simd::{self, KernelKind};
use pobp::engine::traits::LdaParams;
use pobp::sched::{select_power, DocSchedule, PowerParams};
use pobp::synth::{generate, SynthSpec};
use pobp::util::rng::Rng;
use std::sync::{Mutex, OnceLock};

/// The kernel override is process-global; the test harness runs tests on
/// several threads, so every forced-kernel region takes this lock.
fn kernel_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

/// Run `f` with the kernel forced to `kind`, restoring auto-dispatch
/// after (and tolerating a poisoned lock from an earlier test failure).
fn with_kernel<T>(kind: KernelKind, f: impl FnOnce() -> T) -> T {
    let _g = kernel_lock().lock().unwrap_or_else(|e| e.into_inner());
    simd::force_kernel(Some(kind));
    let out = f();
    simd::force_kernel(None);
    out
}

fn fresh_shard(seed: u64, k: usize) -> ShardBp {
    let spec = SynthSpec { docs: 300, ..SynthSpec::tiny(seed) };
    let corpus = generate(&spec).corpus;
    let mut rng = Rng::new(seed);
    ShardBp::init(corpus, k, &mut rng)
}

fn phi_of(shard: &ShardBp) -> (Vec<f32>, Vec<f32>) {
    let phi = shard.dphi.clone();
    let mut tot = vec![0f32; shard.k];
    for row in phi.chunks_exact(shard.k) {
        for (t, &v) in row.iter().enumerate() {
            tot[t] += v;
        }
    }
    (phi, tot)
}

fn assert_bitwise(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "{what}[{i}]: {x} vs {y} (bitwise)"
        );
    }
}

fn assert_shard_bitwise(a: &ShardBp, b: &ShardBp, what: &str) {
    assert_bitwise(&a.mu, &b.mu, &format!("{what}: mu"));
    assert_bitwise(&a.theta, &b.theta, &format!("{what}: theta"));
    assert_bitwise(&a.dphi, &b.dphi, &format!("{what}: dphi"));
    assert_bitwise(&a.r, &b.r, &format!("{what}: r"));
}

/// Serial full-selection sweeps, several rounds, one forced kernel;
/// returns the final shard and every round's residual.
fn run_serial_rounds(kind: KernelKind, seed: u64, k: usize, rounds: usize) -> (ShardBp, Vec<f64>) {
    with_kernel(kind, || {
        let p = LdaParams::paper(k);
        let mut s = fresh_shard(seed, k);
        let sel = Selection::full(s.data.w);
        let mut resids = Vec::with_capacity(rounds);
        for _ in 0..rounds {
            let (phi, tot) = phi_of(&s);
            s.clear_selected_residuals(&sel);
            resids.push(s.sweep(&phi, &tot, &sel, &p, true));
        }
        (s, resids)
    })
}

/// The dense kernel at K = 7 and K = 13 (vector body + scalar tail, and
/// at 7 a tail-heavy row): wide vs scalar bitwise on all state and on
/// every round's residual.
#[test]
fn wide_serial_full_sweep_matches_scalar_bitwise() {
    for &k in &[7usize, 13] {
        let (sa, ra) = run_serial_rounds(KernelKind::Scalar, 101, k, 4);
        let (sb, rb) = run_serial_rounds(KernelKind::Wide, 101, k, 4);
        for (round, (x, y)) in ra.iter().zip(&rb).enumerate() {
            assert!(
                x.to_bits() == y.to_bits(),
                "K={k} round {round}: residual {x} vs {y}"
            );
        }
        assert_shard_bitwise(&sa, &sb, &format!("K={k} serial"));
    }
}

/// The packed-gather subset arm: a power selection with a 3-topic
/// per-word budget (pure tail lanes) driven for several rounds with the
/// selection re-derived from the evolving residuals, wide vs scalar
/// bitwise throughout.
#[test]
fn wide_packed_subset_path_matches_scalar_bitwise() {
    let k = 13usize;
    let run = |kind: KernelKind| -> (ShardBp, Vec<f64>) {
        with_kernel(kind, || {
            let p = LdaParams::paper(k);
            let mut s = fresh_shard(103, k);
            let w = s.data.w;
            // warm with one full sweep so the residual table is non-trivial
            let mut sel = Selection::full(w);
            let mut resids = Vec::new();
            for _ in 0..4 {
                let (phi, tot) = phi_of(&s);
                s.clear_selected_residuals(&sel);
                resids.push(s.sweep(&phi, &tot, &sel, &p, true));
                let ps = select_power(
                    &s.r,
                    w,
                    k,
                    &PowerParams { lambda_w: 0.25, lambda_k_times_k: 3 },
                );
                sel = Selection::from_power(&ps, w);
            }
            (s, resids)
        })
    };
    let (sa, ra) = run(KernelKind::Scalar);
    let (sb, rb) = run(KernelKind::Wide);
    for (round, (x, y)) in ra.iter().zip(&rb).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "round {round}: residual {x} vs {y}"
        );
    }
    assert_shard_bitwise(&sa, &sb, "packed subset");
}

/// Zero-mass rows take the early return identically under both kernels:
/// entries whose μ row is all-zero have mass_old = 0, so the kernel must
/// leave them untouched — the mass folds are scalar under both kernels,
/// so the branch itself cannot diverge.
#[test]
fn zero_mass_rows_early_return_identically() {
    let k = 7usize;
    let run = |kind: KernelKind| -> ShardBp {
        with_kernel(kind, || {
            let p = LdaParams::paper(k);
            let mut s = fresh_shard(107, k);
            // kill the messages of the first 5 entries: mass_old = 0
            for v in s.mu[..5 * k].iter_mut() {
                *v = 0.0;
            }
            let sel = Selection::full(s.data.w);
            let (phi, tot) = phi_of(&s);
            s.clear_selected_residuals(&sel);
            s.sweep(&phi, &tot, &sel, &p, true);
            s
        })
    };
    let sa = run(KernelKind::Scalar);
    let sb = run(KernelKind::Wide);
    assert_shard_bitwise(&sa, &sb, "zero-mass");
    // the zeroed rows really did take the early return (stayed zero)
    assert!(sa.mu[..5 * k].iter().all(|&v| v == 0.0), "zero-mass row was rewritten");
}

/// Thread budgets {1, 2, 8}: at a fixed budget the block partition and
/// merge order are kernel-independent, so the *whole* parallel result —
/// merged Δφ̂/r included — must be bitwise identical between kernels.
#[test]
fn wide_parallel_matches_scalar_parallel_bitwise_across_budgets() {
    let k = 13usize;
    for &budget in &[1usize, 2, 8] {
        let run = |kind: KernelKind| -> (ShardBp, f64) {
            with_kernel(kind, || {
                let p = LdaParams::paper(k);
                let pool = Cluster::new(1, 0);
                let mut s = fresh_shard(109, k);
                let sel = Selection::full(s.data.w);
                let mut resid = 0.0;
                for _ in 0..3 {
                    let (phi, tot) = phi_of(&s);
                    s.clear_selected_residuals(&sel);
                    let (r, _) = s.sweep_parallel(&pool, budget, &phi, &tot, &sel, &p, true);
                    resid = r;
                }
                (s, resid)
            })
        };
        let (sa, ra) = run(KernelKind::Scalar);
        let (sb, rb) = run(KernelKind::Wide);
        assert!(
            ra.to_bits() == rb.to_bits(),
            "budget {budget}: residual {ra} vs {rb}"
        );
        assert_shard_bitwise(&sa, &sb, &format!("budget {budget}"));
    }
}

/// The scheduled-parallel path (ABP's inner sweep) under both kernels:
/// per-doc residuals in schedule order and all state bitwise at budgets
/// {1, 2, 8}, with a power selection so the packed subset arm runs
/// inside the parallel blocks too.
#[test]
fn wide_scheduled_parallel_matches_scalar_bitwise() {
    let k = 7usize;
    for &budget in &[1usize, 2, 8] {
        let run = |kind: KernelKind| -> (ShardBp, Vec<f64>) {
            with_kernel(kind, || {
                let p = LdaParams::paper(k);
                let pool = Cluster::new(1, 0);
                let mut s = fresh_shard(113, k);
                let w = s.data.w;
                // warm one full parallel sweep, then a 40% schedule
                let sel = Selection::full(w);
                let (phi, tot) = phi_of(&s);
                s.sweep_parallel(&pool, budget, &phi, &tot, &sel, &p, true);
                let sched: Vec<u32> =
                    (0..s.data.docs() as u32).filter(|d| d % 5 < 2).collect();
                let ps = select_power(
                    &s.r,
                    w,
                    k,
                    &PowerParams { lambda_w: 0.3, lambda_k_times_k: 3 },
                );
                let sel = Selection::from_power(&ps, w);
                let (phi, tot) = phi_of(&s);
                s.clear_selected_residuals(&sel);
                let ds = DocSchedule::build(&sched, |d| s.data.row_range(d).len());
                let (resids, _) =
                    s.sweep_docs_parallel(&pool, budget, &ds, &phi, &tot, &sel, &p, true);
                (s, resids)
            })
        };
        let (sa, ra) = run(KernelKind::Scalar);
        let (sb, rb) = run(KernelKind::Wide);
        assert_eq!(ra.len(), rb.len());
        for (i, (x, y)) in ra.iter().zip(&rb).enumerate() {
            assert!(
                x.to_bits() == y.to_bits(),
                "budget {budget} sched slot {i}: residual {x} vs {y}"
            );
        }
        assert_shard_bitwise(&sa, &sb, &format!("scheduled budget {budget}"));
    }
}

/// Dispatch sanity: auto mode resolves to the wide kernel exactly when
/// the feature (and a supported arch) compiled it in; the scalar build
/// never runs wide lanes even when forced.
#[test]
fn kernel_dispatch_tracks_feature_flag() {
    let _g = kernel_lock().lock().unwrap_or_else(|e| e.into_inner());
    simd::force_kernel(None);
    let auto = simd::active_kernel();
    if simd::wide_compiled() {
        assert_eq!(auto, KernelKind::Wide);
    } else {
        assert_eq!(auto, KernelKind::Scalar);
        simd::force_kernel(Some(KernelKind::Wide));
        assert_eq!(simd::active_kernel(), KernelKind::Scalar, "scalar build must stay scalar");
    }
    simd::force_kernel(Some(KernelKind::Scalar));
    assert_eq!(simd::active_kernel(), KernelKind::Scalar);
    simd::force_kernel(None);
    assert_eq!(simd::active_kernel(), auto);
}
