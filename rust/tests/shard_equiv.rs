//! Contract 5 acceptance: the **sharded** φ̂ storage mode is bitwise
//! interchangeable with the replicated oracle.
//!
//! * A sharded coordinator run (`PhiStorageMode::Sharded`: φ̂ and r held
//!   as row-aligned owner slices, sweeps reading rows in place through
//!   `PhiView::Slices`, the allreduce folding into the stored slices)
//!   must be bitwise identical to the replicated run — model bits,
//!   per-iteration residual history, synced pair counts — at OS-thread
//!   budgets {1, 2, 8}, for the full and the power schedule, across
//!   worker counts.
//! * The byte accounting must agree where the modes are semantically
//!   identical: same sync schedule, same reduce payload; the sharded
//!   ledger additionally attributes the working-set allgather.
//! * Stepwise: `ShardedState` driven by real `ShardBp` sweeps must track
//!   `GlobalState` bitwise (slices, totals) round for round.

use std::sync::Mutex;

use pobp::comm::allreduce::{
    allreduce_step, allreduce_step_sharded, GlobalState, OwnerSlices, ReducePlan,
    ShardedState, SyncScratch,
};
use pobp::comm::Cluster;
use pobp::coordinator::{fit, PobpConfig};
use pobp::corpus::shard_ranges;
use pobp::engine::bp::{PhiView, Selection, ShardBp};
use pobp::engine::traits::{LdaParams, TrainResult};
use pobp::sched::{select_power, select_power_sharded, PowerParams};
use pobp::storage::PhiStorageMode;
use pobp::synth::{generate, SynthSpec};
use pobp::util::rng::Rng;

/// Fit the same corpus in both storage modes and assert the bitwise
/// contract: identical model, identical residual trajectory, identical
/// pair counts and sync schedule.
fn fit_case(n_workers: usize, threads: usize, power: PowerParams, seed: u64) {
    let corpus = generate(&SynthSpec::tiny(seed)).corpus;
    let params = LdaParams::paper(8);
    let base = PobpConfig {
        n_workers,
        max_threads: threads,
        nnz_budget: 900,
        power,
        max_iters: 8,
        converge_thresh: 0.0, // pin the iteration count
        ..Default::default()
    };
    let rep: TrainResult = fit(&corpus, &params, &base);
    let sh: TrainResult = fit(
        &corpus,
        &params,
        &PobpConfig { storage: PhiStorageMode::Sharded, ..base },
    );
    let ctx = format!("n={n_workers}, threads={threads}");
    assert_eq!(sh.model.phi_wk, rep.model.phi_wk, "model diverged at {ctx}");
    assert_eq!(sh.history.len(), rep.history.len(), "{ctx}");
    for (a, b) in sh.history.iter().zip(&rep.history) {
        assert_eq!(
            a.residual_per_token.to_bits(),
            b.residual_per_token.to_bits(),
            "batch {} iter {} residual diverged at {ctx}",
            a.batch,
            a.iter
        );
        assert_eq!(a.synced_pairs, b.synced_pairs, "{ctx}");
    }
    // identical sync schedule and reduce payload; the wire bytes differ
    // only by the sharded working-set gather attribution
    assert_eq!(sh.ledger.sync_count(), rep.ledger.sync_count(), "{ctx}");
    assert_eq!(
        sh.ledger.payload_bytes_total(),
        rep.ledger.payload_bytes_total(),
        "{ctx}"
    );
}

/// The acceptance sweep of ISSUE 6: thread budgets 1/2/8 — the owner
/// partition derives from the logical worker count only, so every
/// OS-thread budget must produce the same bits.
#[test]
fn sharded_fit_bitwise_equals_replicated_all_thread_budgets() {
    for &threads in &[1usize, 2, 8] {
        fit_case(3, threads, PowerParams::paper_default(), 41);
    }
}

#[test]
fn sharded_fit_bitwise_equals_replicated_full_schedule() {
    for &threads in &[1usize, 2, 8] {
        fit_case(2, threads, PowerParams::full(), 42);
    }
}

#[test]
fn sharded_fit_bitwise_equals_replicated_across_worker_counts() {
    for n in [1usize, 2, 4, 5] {
        fit_case(n, 0, PowerParams { lambda_w: 0.2, lambda_k_times_k: 3 }, 43);
    }
}

/// Stepwise pin with real sweep output: drive `ShardedState` and
/// `GlobalState` through the same sweep + sync rounds (dense first, then
/// power subsets selected from the sharded residual slices) and assert
/// the stored slices concatenate to the oracle's replicas bitwise,
/// totals included, while each worker's resident φ̂ stays one slice.
#[test]
fn sharded_state_tracks_global_state_through_real_sweeps() {
    let seed = 51;
    let corpus = generate(&SynthSpec::tiny(seed)).corpus;
    let k = 8;
    let w = corpus.w;
    let params = LdaParams::paper(k);
    let n = 3;
    let cluster = Cluster::new(n, 0);
    let mut rng = Rng::new(seed);

    let ranges = shard_ranges(corpus.docs(), n);
    let shards: Vec<Mutex<ShardBp>> = ranges
        .iter()
        .enumerate()
        .map(|(i, rg)| {
            let mut wrng = rng.split(i as u64);
            Mutex::new(ShardBp::init(corpus.slice_docs(rg.start, rg.end), k, &mut wrng))
        })
        .collect();

    // non-trivial accumulator so the φ̂_acc seeding path is covered
    let phi_acc: Vec<f32> = (0..w * k).map(|_| rng.f32() * 0.1).collect();
    let os = OwnerSlices::row_aligned(w * k, k, n);
    let acc_parts: Vec<Vec<f32>> =
        (0..n).map(|i| phi_acc[os.range(i)].to_vec()).collect();

    let mut rep = GlobalState::new(&phi_acc, k);
    let mut sh = ShardedState::new(&acc_parts, k, os);
    let mut scr_rep = SyncScratch::default();
    let mut scr_sh = SyncScratch::default();
    let mut selection = Selection::full(w);
    let mut flat: Option<Vec<u32>> = None;
    let pp = PowerParams { lambda_w: 0.15, lambda_k_times_k: 4 };
    let full_bytes = 2 * 4 * w * k;

    for t in 0..6 {
        // sweep against the sharded state's slice view — the bits the
        // replicated state would hand the kernels are identical, pinned
        // below, so one sweep drives both reductions
        let budget = cluster.doc_threads_per_worker();
        {
            let parts = sh.phi_parts();
            let view = PhiView::Slices { parts: &parts, rows_per: sh.rows_per() };
            let tot = sh.phi_tot();
            let sel = &selection;
            cluster.run(|i| {
                let mut g = shards[i].lock().unwrap();
                g.sweep_parallel_view(&cluster, budget, view, tot, sel, &params, true)
            });
        }

        let plan = match &flat {
            None => ReducePlan::Dense { len: w * k },
            Some(ix) => ReducePlan::Subset { indices: ix },
        };
        let pairs_rep =
            allreduce_step(&cluster, &plan, &phi_acc, &shards, &mut rep, &mut scr_rep);
        let pairs_sh = allreduce_step_sharded(
            &cluster, &plan, &acc_parts, &shards, &mut sh, &mut scr_sh,
        );
        let ctx = format!("t={t}");
        assert_eq!(pairs_rep, pairs_sh, "{ctx}");
        assert_eq!(sh.render_dense(), rep.phi_eff, "phi slices diverged at {ctx}");
        let r_cat: Vec<f32> = sh.r_parts().concat();
        assert_eq!(r_cat, rep.r_global, "r slices diverged at {ctx}");
        assert_eq!(sh.phi_tot(), rep.phi_tot(), "totals diverged at {ctx}");
        assert_eq!(sh.r_total().to_bits(), rep.r_total().to_bits(), "{ctx}");
        // the memory claim, live: one worker's resident φ̂ + r is its
        // owner slice pair, not the 2·4·W·K replica
        assert_eq!(sh.resident_bytes_per_worker(), 2 * 4 * os.per());
        assert!(sh.resident_bytes_per_worker() < full_bytes);

        // next schedule from the sharded residual slices — must equal
        // the dense selection bitwise (tie-breaking included)
        let ps_sh = select_power_sharded(&sh.r_parts(), sh.rows_per(), w, k, &pp);
        let ps_rep = select_power(&rep.r_global, w, k, &pp);
        assert_eq!(ps_sh, ps_rep, "selection diverged at {ctx}");
        flat = Some(ps_sh.flat_indices(k));
        selection = Selection::from_power(&ps_sh, w);
    }
}
