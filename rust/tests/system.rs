//! System-level integration tests: whole-pipeline invariants that cross
//! module boundaries (corpus I/O → engines → evaluation → ledger), plus
//! failure-injection cases.

use pobp::comm::NetModel;
use pobp::coordinator::{fit, PobpConfig};
use pobp::corpus::{bow, split_tokens, Csr, MiniBatchStream};
use pobp::engine::traits::{LdaParams, Model};
use pobp::eval::perplexity::{heldin_perplexity, predictive_perplexity};
use pobp::repro::{dataset, run_algo, Algo, RunOpts};
use pobp::sched::PowerParams;
use pobp::util::prop::check;

fn tiny() -> Csr {
    dataset("tiny", 1, 8, 99)
}

/// Corpus → disk → corpus → train → eval, end to end.
#[test]
fn disk_roundtrip_then_train() {
    let c = tiny();
    let dir = std::env::temp_dir().join("pobp_system_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("docword.tiny.txt");
    let f = std::fs::File::create(&path).unwrap();
    bow::write_uci(&c, std::io::BufWriter::new(f)).unwrap();
    let c2 = bow::read_uci(&path).unwrap();
    assert_eq!(c2.nnz(), c.nnz());

    let params = LdaParams::paper(8);
    let r = fit(&c2, &params, &PobpConfig { n_workers: 2, ..Default::default() });
    let p = heldin_perplexity(&r.model, &c2, &params);
    assert!(p < c.w as f64 * 0.5, "model did not learn: {p}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Model save/load roundtrip preserves evaluation exactly.
#[test]
fn model_serialization_roundtrip() {
    let c = tiny();
    let params = LdaParams::paper(8);
    let r = run_algo(Algo::Psgs, &c, &params, &RunOpts { iters: 10, ..Default::default() });
    let path = std::env::temp_dir().join("pobp_model_roundtrip.bin");
    r.model.save(&path).unwrap();
    let loaded = Model::load(&path).unwrap();
    assert_eq!(loaded.phi_wk, r.model.phi_wk);
    assert_eq!((loaded.w, loaded.k), (r.model.w, r.model.k));
    std::fs::remove_file(&path).ok();
}

/// Corrupt model files are rejected, not mis-read.
#[test]
fn corrupt_model_rejected() {
    let path = std::env::temp_dir().join("pobp_corrupt.bin");
    std::fs::write(&path, b"definitely not a model").unwrap();
    assert!(Model::load(&path).is_err());
    std::fs::remove_file(&path).ok();
}

/// The ledger's cost decomposition is conserved across reruns and scales
/// sanely with N (communication grows with N at fixed payload).
#[test]
fn ledger_cost_decomposition_sane() {
    let c = dataset("enron", 400, 16, 7);
    let params = LdaParams::paper(16);
    let small = run_algo(Algo::Pgs, &c, &params, &RunOpts { n_workers: 2, iters: 5, ..Default::default() });
    let large = run_algo(Algo::Pgs, &c, &params, &RunOpts { n_workers: 32, iters: 5, ..Default::default() });
    assert!(large.ledger.comm_secs > small.ledger.comm_secs);
    assert_eq!(small.ledger.sync_count(), large.ledger.sync_count());
    // same per-processor payload, more processors => more wire bytes
    assert!(large.ledger.wire_bytes > small.ledger.wire_bytes);
}

/// POBP with degenerate corpora must not panic or lose mass (failure
/// injection: pathological shard shapes, empty workers, empty corpus).
#[test]
fn degenerate_corpora_survive() {
    let params = LdaParams::paper(4);
    // single doc, more workers than docs
    let c = Csr::from_docs(10, &[vec![(0, 3.0), (9, 1.0)]]);
    let r = fit(&c, &params, &PobpConfig { n_workers: 8, ..Default::default() });
    assert!((r.model.mass() - 4.0).abs() < 1e-3);
    // corpus with empty documents interleaved
    let c = Csr::from_docs(5, &[vec![], vec![(1, 2.0)], vec![], vec![(4, 1.0)], vec![]]);
    let r = fit(&c, &params, &PobpConfig { n_workers: 3, ..Default::default() });
    assert!((r.model.mass() - 3.0).abs() < 1e-3);
    // empty corpus
    let c = Csr::from_docs(5, &[]);
    let r = fit(&c, &params, &PobpConfig { n_workers: 2, ..Default::default() });
    assert_eq!(r.model.mass(), 0.0);
}

/// Mini-batch streaming composes with training: any batch budget gives
/// the same token mass.
#[test]
fn minibatch_count_does_not_change_mass() {
    let c = tiny();
    let params = LdaParams::paper(8);
    for budget in [200usize, 1000, usize::MAX] {
        let m = MiniBatchStream::count(&c, budget);
        let r = fit(&c, &params, &PobpConfig {
            n_workers: 2,
            nnz_budget: budget,
            ..Default::default()
        });
        assert!(
            (r.model.mass() - c.tokens()).abs() < c.tokens() * 1e-3,
            "budget {budget} ({m} batches)"
        );
    }
}

/// Determinism: identical seeds → identical models (across the whole
/// pipeline, including the threaded cluster).
#[test]
fn full_run_deterministic() {
    let c = tiny();
    let params = LdaParams::paper(8);
    let cfg = PobpConfig { n_workers: 4, ..Default::default() };
    let a = fit(&c, &params, &cfg);
    let b = fit(&c, &params, &cfg);
    assert_eq!(a.model.phi_wk, b.model.phi_wk);
    assert_eq!(a.history.len(), b.history.len());
}

/// Property: across random corpora, POBP's synchronized payload is never
/// larger than the full-matrix payload, and both conserve token mass.
#[test]
fn prop_payload_bounded_by_full() {
    check("payload bounded", 10, |rng| {
        let d = rng.range(10, 40);
        let w = rng.range(20, 60);
        let docs: Vec<Vec<(u32, f32)>> = (0..d)
            .map(|_| {
                (0..rng.range(2, 10))
                    .map(|_| (rng.below(w) as u32, rng.range(1, 4) as f32))
                    .collect()
            })
            .collect();
        let c = Csr::from_docs(w, &docs);
        let params = LdaParams::paper(6);
        let base = PobpConfig {
            n_workers: 2,
            max_iters: 8,
            converge_thresh: 0.0,
            seed: rng.next_u64(),
            ..Default::default()
        };
        let full = fit(&c, &params, &PobpConfig { power: PowerParams::full(), ..base.clone() });
        let pow = fit(&c, &params, &PobpConfig {
            power: PowerParams { lambda_w: 0.3, lambda_k_times_k: 3 },
            ..base
        });
        assert!(pow.ledger.payload_bytes_total() <= full.ledger.payload_bytes_total());
        assert!((pow.model.mass() - c.tokens()).abs() < c.tokens() * 1e-3);
    });
}

/// The network model's monotonicity carries through whole runs: a slower
/// network makes the *simulated* time larger, never the model different.
#[test]
fn network_speed_affects_time_not_result() {
    let c = tiny();
    let params = LdaParams::paper(8);
    let mk = |net| PobpConfig { n_workers: 4, net, ..Default::default() };
    let fast = fit(&c, &params, &mk(NetModel::infiniband_20gbps()));
    let slow = fit(&c, &params, &mk(NetModel::gige()));
    assert_eq!(fast.model.phi_wk, slow.model.phi_wk);
    assert!(slow.ledger.comm_secs > fast.ledger.comm_secs);
}

/// A model trained on one topic structure evaluates better on its own
/// corpus than on a differently-seeded one (generalization direction).
#[test]
fn eval_prefers_matching_corpus() {
    let params = LdaParams::paper(8);
    let a = dataset("tiny", 1, 8, 5);
    let b = {
        let mut spec = pobp::synth::SynthSpec::tiny(1234);
        spec.docs = 120;
        pobp::synth::generate(&spec).corpus
    };
    let r = fit(&a, &params, &PobpConfig { n_workers: 2, ..Default::default() });
    let split_a = split_tokens(&a, 0.2, 1);
    let split_b = split_tokens(&b, 0.2, 1);
    let p_own = predictive_perplexity(&r.model, &split_a, &params, 15, 2);
    let p_other = predictive_perplexity(&r.model, &split_b, &params, 15, 2);
    assert!(p_own < p_other, "own {p_own} vs other {p_other}");
}

/// Gibbs, BP and VB families agree on the quality scale: perplexities on
/// the same split are within a factor of 2 (catches protocol or scaling
/// bugs in any one engine).
#[test]
fn engines_agree_on_quality_scale() {
    let c = dataset("tiny", 1, 8, 31);
    let params = LdaParams::paper(8);
    let split = split_tokens(&c, 0.2, 31);
    let o = RunOpts { n_workers: 2, iters: 40, ..Default::default() };
    let mut perps = Vec::new();
    for algo in [Algo::Pobp, Algo::Psgs, Algo::Pvb] {
        let r = run_algo(algo, &split.train, &params, &o);
        let p = predictive_perplexity(&r.model, &split, &params, 15, 31);
        perps.push((algo.name(), p));
    }
    let min = perps.iter().map(|&(_, p)| p).fold(f64::INFINITY, f64::min);
    for (name, p) in &perps {
        assert!(*p < 2.0 * min, "{name} perplexity {p} off-scale vs {min}");
    }
}
