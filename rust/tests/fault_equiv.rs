//! Contract 6 acceptance: fault-tolerant training recovers **bitwise**.
//!
//! A run that is killed at a chosen `(batch, iter, sync-phase)` point and
//! recovered from the last crash-consistent checkpoint must end bitwise
//! identical to an uninterrupted oracle — model bits, residual history,
//! per-topic f64 totals, sync counts and payload bytes — at OS-thread
//! budgets {1, 2, 8} and in both φ̂ storage modes. Only the ledger's side
//! accumulators (checkpoint I/O, recovery replay, straggler wait) may
//! record that the road was bumpy; `total_secs()` keeps fault-free bits.
//!
//! Also pinned here: a corrupted newest checkpoint is refused and the
//! previous good one is used instead; a batch-0 kill (no checkpoint yet)
//! recovers by replaying from scratch; injected straggler delays never
//! change the numerics; `max_retries = 0` surfaces `RetriesExhausted`.

use std::path::PathBuf;

use pobp::coordinator::{
    fit, fit_resilient, PobpConfig, ResilienceConfig, TrainError,
};
use pobp::engine::traits::{LdaParams, TrainResult};
use pobp::fault::{FaultKind, FaultPlan, FaultSpec, SyncPhase};
use pobp::storage::checkpoint::list_checkpoints;
use pobp::storage::{Checkpoint, PhiStorageMode};
use pobp::synth::{generate, SynthSpec};

/// Pinned harness: N = 3 workers, per-processor budget 300 (global 900,
/// several mini-batches on the tiny corpus), exactly 8 iterations per
/// batch (`converge_thresh = 0` pins the count, so the fold boundary is
/// always iteration 9).
const MAX_ITERS: usize = 8;
const FOLD_ITER: usize = MAX_ITERS + 1;

fn cfg(threads: usize, storage: PhiStorageMode) -> PobpConfig {
    PobpConfig {
        n_workers: 3,
        max_threads: threads,
        nnz_budget: 300,
        max_iters: MAX_ITERS,
        converge_thresh: 0.0,
        storage,
        ..Default::default()
    }
}

fn corpus() -> pobp::corpus::Csr {
    generate(&SynthSpec::tiny(29)).corpus
}

/// Fresh scratch directory for one test case.
fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("pobp-fault-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Sequential per-topic f64 sums over the dense row-major model — the
/// same fold order the checkpoint's TOTALS section pins.
fn topic_totals(r: &TrainResult) -> Vec<u64> {
    let (w, k) = (r.model.w, r.model.k);
    let mut tot = vec![0f64; k];
    for wi in 0..w {
        for t in 0..k {
            tot[t] += r.model.phi_wk[wi * k + t] as f64;
        }
    }
    tot.iter().map(|v| v.to_bits()).collect()
}

/// The bitwise-recovery contract between a recovered run and its
/// uninterrupted oracle.
fn assert_bitwise_equal(got: &TrainResult, oracle: &TrainResult, ctx: &str) {
    assert_eq!(got.model.phi_wk, oracle.model.phi_wk, "model diverged at {ctx}");
    assert_eq!(topic_totals(got), topic_totals(oracle), "totals diverged at {ctx}");
    assert_eq!(got.history.len(), oracle.history.len(), "history length at {ctx}");
    for (a, b) in got.history.iter().zip(&oracle.history) {
        assert_eq!(a.batch, b.batch, "{ctx}");
        assert_eq!(a.iter, b.iter, "{ctx}");
        assert_eq!(
            a.residual_per_token.to_bits(),
            b.residual_per_token.to_bits(),
            "batch {} iter {} residual diverged at {ctx}",
            a.batch,
            a.iter
        );
        assert_eq!(a.synced_pairs, b.synced_pairs, "{ctx}");
    }
    assert_eq!(got.ledger.sync_count(), oracle.ledger.sync_count(), "{ctx}");
    assert_eq!(
        got.ledger.payload_bytes_total(),
        oracle.ledger.payload_bytes_total(),
        "{ctx}"
    );
    assert_eq!(got.ledger.wire_bytes, oracle.ledger.wire_bytes, "{ctx}");
    assert_eq!(
        got.ledger.total_secs().to_bits(),
        oracle.ledger.total_secs().to_bits(),
        "recovery leaked into total_secs at {ctx}"
    );
}

/// Kill a run at one point, recover it, and pin the result against the
/// uninterrupted oracle.
fn kill_and_recover_case(
    tag: &str,
    threads: usize,
    storage: PhiStorageMode,
    batch: usize,
    iter: usize,
    phase: SyncPhase,
) {
    let c = corpus();
    let params = LdaParams::paper(8);
    let cfg = cfg(threads, storage);
    let oracle = fit(&c, &params, &cfg);
    let batches = oracle.history.iter().map(|s| s.batch).max().unwrap() + 1;
    assert!(batches >= 2, "harness must be multi-batch, got {batches}");
    assert!(batch < batches, "kill point past the run ({batch} >= {batches})");

    let dir = tmpdir(tag);
    let res = ResilienceConfig::in_dir(&dir);
    let plan = FaultPlan::kill(batch, iter, phase, 1);
    let got = fit_resilient(&c, &params, &cfg, &res, Some(&plan))
        .unwrap_or_else(|e| panic!("{tag}: recovery failed: {e}"));
    assert_eq!(plan.kills_remaining(), 0, "{tag}: the kill never fired");
    assert!(got.ledger.recovery_count >= 1, "{tag}: no recovery recorded");
    if batch > 0 {
        // recovery resumed mid-stream, so the replay charge is bounded
        // by the death clock minus the checkpoint clock
        assert!(
            got.ledger.recovery_replay_secs >= 0.0
                && got.ledger.recovery_replay_secs <= got.ledger.total_secs(),
            "{tag}: implausible replay charge {}",
            got.ledger.recovery_replay_secs
        );
        assert!(got.ledger.checkpoint_count >= 1, "{tag}: nothing checkpointed");
    }
    let ctx = format!("{tag} (threads={threads}, {storage:?}, {phase:?})");
    assert_bitwise_equal(&got, &oracle, &ctx);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The acceptance matrix: kill points at the start-of-iteration sweep,
/// inside the allreduce boundary, and at the end-of-batch fold — each at
/// thread budgets 1/2/8, in both storage modes.
#[test]
fn killed_runs_recover_bitwise_at_sweep() {
    for &threads in &[1usize, 2, 8] {
        for storage in [PhiStorageMode::Replicated, PhiStorageMode::Sharded] {
            kill_and_recover_case(
                &format!("sweep-{threads}-{storage:?}"),
                threads,
                storage,
                1,
                1,
                SyncPhase::Sweep,
            );
        }
    }
}

#[test]
fn killed_runs_recover_bitwise_at_mid_reduce() {
    for &threads in &[1usize, 2, 8] {
        for storage in [PhiStorageMode::Replicated, PhiStorageMode::Sharded] {
            kill_and_recover_case(
                &format!("midreduce-{threads}-{storage:?}"),
                threads,
                storage,
                1,
                3,
                SyncPhase::MidReduce,
            );
        }
    }
}

#[test]
fn killed_runs_recover_bitwise_at_fold() {
    for &threads in &[1usize, 2, 8] {
        for storage in [PhiStorageMode::Replicated, PhiStorageMode::Sharded] {
            kill_and_recover_case(
                &format!("fold-{threads}-{storage:?}"),
                threads,
                storage,
                1,
                FOLD_ITER,
                SyncPhase::Fold,
            );
        }
    }
}

/// A batch-0 kill happens before any checkpoint exists: recovery must
/// replay from scratch and still land on the oracle's bits.
#[test]
fn batch_zero_kill_recovers_from_scratch() {
    for storage in [PhiStorageMode::Replicated, PhiStorageMode::Sharded] {
        kill_and_recover_case(
            &format!("batch0-{storage:?}"),
            0,
            storage,
            0,
            2,
            SyncPhase::Sweep,
        );
    }
}

/// The overlap pipeline goes through the same recovery protocol.
#[test]
fn overlap_mode_kill_recovers_bitwise() {
    let c = corpus();
    let params = LdaParams::paper(8);
    let cfg = PobpConfig { overlap: true, ..cfg(0, PhiStorageMode::Replicated) };
    let oracle = fit(&c, &params, &cfg);
    let dir = tmpdir("overlap");
    let res = ResilienceConfig::in_dir(&dir);
    let plan = FaultPlan::kill(1, 2, SyncPhase::MidReduce, 0);
    let got = fit_resilient(&c, &params, &cfg, &res, Some(&plan))
        .expect("overlap recovery");
    assert!(got.ledger.recovery_count >= 1);
    assert_bitwise_equal(&got, &oracle, "overlap mid-reduce kill");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Flip one byte of the newest checkpoint: the load must refuse it and
/// fall back to the previous good file, and the resumed run still ends
/// on the oracle's bits.
#[test]
fn corrupt_checkpoint_falls_back_to_previous_good() {
    let c = corpus();
    let params = LdaParams::paper(8);
    let cfg = cfg(2, PhiStorageMode::Replicated);
    let oracle = fit(&c, &params, &cfg);

    let dir = tmpdir("corrupt");
    let mut res = ResilienceConfig::in_dir(&dir);
    res.keep_checkpoints = 4;
    // clean run that leaves a trail of checkpoints behind
    let clean = fit_resilient(&c, &params, &cfg, &res, None).expect("clean run");
    assert!(clean.ledger.checkpoint_count >= 2, "need ≥ 2 checkpoints on disk");
    let files = list_checkpoints(&dir).expect("list checkpoints");
    assert!(files.len() >= 2, "retention kept {} files", files.len());
    let newest = files.last().unwrap().clone();

    // flip a byte in the middle of the newest file
    let mut bytes = std::fs::read(&newest).expect("read checkpoint");
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&newest, &bytes).expect("write corruption");
    assert!(
        Checkpoint::load(&newest).is_err(),
        "corrupted checkpoint must be refused"
    );

    // resume: the loader must skip the corrupt newest file, restore the
    // previous good one, and the continuation must still be bitwise
    res.resume = true;
    let resumed = fit_resilient(&c, &params, &cfg, &res, None).expect("resumed run");
    assert_bitwise_equal(&resumed, &oracle, "corrupt-fallback resume");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Straggler delays reorder nothing: the numerics stay bitwise, the wait
/// shows up only in the ledger's side accumulators.
#[test]
fn straggler_delays_never_change_the_numerics() {
    let c = corpus();
    let params = LdaParams::paper(8);
    let cfg = cfg(0, PhiStorageMode::Replicated);
    let oracle = fit(&c, &params, &cfg);
    let dir = tmpdir("delay");
    let res = ResilienceConfig::in_dir(&dir);
    let plan = FaultPlan::new(vec![
        FaultSpec {
            batch: 0,
            iter: 2,
            phase: SyncPhase::Sweep,
            worker: 1,
            kind: FaultKind::Delay { secs: 0.25 },
        },
        FaultSpec {
            batch: 1,
            iter: 4,
            phase: SyncPhase::Sweep,
            worker: 2,
            kind: FaultKind::Delay { secs: 0.5 },
        },
    ]);
    let got = fit_resilient(&c, &params, &cfg, &res, Some(&plan)).expect("delayed run");
    assert!(
        got.ledger.straggler_wait_secs > 0.0,
        "delays charged no straggler wait"
    );
    assert!(got.ledger.straggler_polls >= 1);
    assert_bitwise_equal(&got, &oracle, "straggler delays");
    assert!(got.ledger.degraded_total_secs() > got.ledger.total_secs());
    let _ = std::fs::remove_dir_all(&dir);
}

/// With a zero retry budget the first kill is terminal.
#[test]
fn zero_retry_budget_surfaces_retries_exhausted() {
    let c = corpus();
    let params = LdaParams::paper(8);
    let cfg = cfg(0, PhiStorageMode::Replicated);
    let dir = tmpdir("exhausted");
    let mut res = ResilienceConfig::in_dir(&dir);
    res.max_retries = 0;
    let plan = FaultPlan::kill(0, 1, SyncPhase::Sweep, 0);
    match fit_resilient(&c, &params, &cfg, &res, Some(&plan)) {
        Err(TrainError::RetriesExhausted { fault, retries }) => {
            assert_eq!(retries, 0);
            assert_eq!(fault.batch, 0);
            assert_eq!(fault.iter, 1);
            assert_eq!(fault.phase, SyncPhase::Sweep);
        }
        Err(other) => panic!("unexpected error: {other}"),
        Ok(_) => panic!("a kill with zero retries must fail the run"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
