//! Contract 9 acceptance: any chaos schedule that eventually lets
//! frames through ends **bitwise identical** to the fault-free oracle.
//!
//! A deterministic [`ChaosPlan`] injects wire faults — payload
//! bit-flips, mid-frame truncations, dropped frames (half-open hangs),
//! connection resets, duplicated frames, per-frame delays — at the
//! master's socket edge, pinned to exact `(batch, iter, slot, frame
//! kind)` exchange points or drawn from a seed. The supervised
//! transport (per-frame retry, idempotent same-seq resend, worker
//! rejoin with capped backoff) must recover every one of them such
//! that model bits, residual history, pair counts and the modeled sync
//! schedule equal an undisturbed `fit` — while the recovery effort
//! (retransmitted frames/bytes, reconnects, backoff waits) lands in
//! the ledger's side accumulators and never in `total_secs()`.
//!
//! Faults are exercised on both carriers (in-process codec and real
//! TCP worker processes), at all three exchange frames (Batch/BatchAck,
//! Sweep/Gather, Fold/FoldPart), in both storage modes, at 2 and 3
//! workers.

use std::path::PathBuf;
use std::time::Duration;

use pobp::comm::transport::{InProcessTransport, TcpSpawnSpec, TcpTransport, Transport};
use pobp::comm::wire::FrameKind;
use pobp::coordinator::{fit, fit_dist, PobpConfig};
use pobp::engine::traits::{LdaParams, TrainResult};
use pobp::fault::{ChaosFault, ChaosPlan, ChaosSpec};
use pobp::sched::PowerParams;
use pobp::storage::PhiStorageMode;
use pobp::synth::{generate, SynthSpec};

fn params() -> LdaParams {
    LdaParams::paper(8)
}

/// Same shape as `dist_equiv.rs`: converge_thresh 0 pins every batch at
/// exactly `max_iters` sweep iterations, so the chaos exchange points
/// (Batch = iter 0, Sweep/Gather = iter t, Fold = `max_iters + 1`) are
/// deterministic coordinates.
const MAX_ITERS: usize = 7;
const FOLD_ITER: usize = MAX_ITERS + 1;

fn cfg_for(n_workers: usize, storage: PhiStorageMode) -> PobpConfig {
    PobpConfig {
        n_workers,
        max_threads: 1,
        nnz_budget: 600,
        power: PowerParams::paper_default(),
        max_iters: MAX_ITERS,
        converge_thresh: 0.0,
        snapshot_every: 3,
        storage,
        ..Default::default()
    }
}

fn worker_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_pobp-worker"))
}

fn spec(iter: usize, slot: usize, kind: FrameKind, fault: ChaosFault) -> ChaosSpec {
    ChaosSpec { batch: 0, iter, slot, kind, fault }
}

/// The deterministic-quantity pin of `dist_equiv.rs`, verbatim: model
/// bits, residual history, pair counts, sync/byte schedule, modeled
/// per-segment comm seconds, snapshot model bits. Wall-measured
/// seconds and the Contract 9 side accumulators are never compared.
fn assert_equiv(dist: &TrainResult, oracle: &TrainResult, ctx: &str) {
    assert_eq!(dist.model.phi_wk, oracle.model.phi_wk, "model diverged at {ctx}");
    assert_eq!(dist.history.len(), oracle.history.len(), "history len at {ctx}");
    for (a, b) in dist.history.iter().zip(&oracle.history) {
        assert_eq!((a.batch, a.iter), (b.batch, b.iter), "schedule at {ctx}");
        assert_eq!(
            a.residual_per_token.to_bits(),
            b.residual_per_token.to_bits(),
            "batch {} iter {} residual diverged at {ctx}",
            a.batch,
            a.iter
        );
        assert_eq!(a.synced_pairs, b.synced_pairs, "pairs at {ctx}");
    }
    assert_eq!(dist.ledger.sync_count(), oracle.ledger.sync_count(), "{ctx}");
    assert_eq!(
        dist.ledger.payload_bytes_total(),
        oracle.ledger.payload_bytes_total(),
        "{ctx}"
    );
    assert_eq!(dist.ledger.wire_bytes, oracle.ledger.wire_bytes, "{ctx}");
    for (a, b) in dist.ledger.events.iter().zip(&oracle.ledger.events) {
        assert_eq!((a.batch, a.iter), (b.batch, b.iter), "event schedule at {ctx}");
        assert_eq!(a.payload_bytes, b.payload_bytes, "{ctx}");
        assert_eq!(a.comm_secs.to_bits(), b.comm_secs.to_bits(), "{ctx}");
        assert_eq!(
            a.reduce_scatter_secs.to_bits(),
            b.reduce_scatter_secs.to_bits(),
            "{ctx}"
        );
        assert_eq!(a.allgather_secs.to_bits(), b.allgather_secs.to_bits(), "{ctx}");
    }
    assert_eq!(dist.snapshots.len(), oracle.snapshots.len(), "snapshots at {ctx}");
    for ((_, a), (_, b)) in dist.snapshots.iter().zip(&oracle.snapshots) {
        assert_eq!(a.phi_wk, b.phi_wk, "snapshot model diverged at {ctx}");
    }
    // the fault-free oracle accumulated no recovery effort (total_secs
    // itself holds wall-measured compute and is never compared across
    // runs; the ledger unit tests pin that the side accumulators stay
    // out of it)
    assert_eq!(oracle.ledger.chaos_faults, 0, "{ctx}");
    assert_eq!(oracle.ledger.retrans_frames, 0, "{ctx}");
    assert_eq!(oracle.ledger.reconnects, 0, "{ctx}");
}

/// Every fault type at every frame kind, through the in-process codec
/// carrier, both storage modes. Bit-flips and truncations are refused
/// and retransmitted; drops/resets retransmit; the duplicate applies
/// once; the delay is absorbed.
#[test]
fn inprocess_chaos_pinned_bitwise_equals_fit() {
    let plan = ChaosPlan::pinned(vec![
        spec(0, 0, FrameKind::Batch, ChaosFault::FlipBit),
        spec(0, 1, FrameKind::BatchAck, ChaosFault::Truncate),
        spec(2, 0, FrameKind::Sweep, ChaosFault::Reset),
        spec(3, 1, FrameKind::Gather, ChaosFault::Drop),
        spec(5, 1, FrameKind::Sweep, ChaosFault::Delay { ms: 1 }),
        spec(FOLD_ITER, 0, FrameKind::Fold, ChaosFault::Duplicate),
        spec(FOLD_ITER, 1, FrameKind::FoldPart, ChaosFault::FlipBit),
    ]);
    for &storage in &[PhiStorageMode::Replicated, PhiStorageMode::Sharded] {
        let corpus = generate(&SynthSpec::tiny(43)).corpus;
        let cfg = cfg_for(2, storage);
        let oracle = fit(&corpus, &params(), &cfg);
        let mut tp = InProcessTransport::new(2, 1).with_chaos(plan.clone());
        let r = fit_dist(&corpus, &params(), &cfg, &mut tp).expect("chaos dist fit");
        let ctx = format!("inprocess pinned chaos {storage:?}");
        assert_equiv(&r, &oracle, &ctx);
        // every pinned point fired once and was recovered
        assert_eq!(r.ledger.chaos_faults, plan.specs().len() as u64, "{ctx}");
        assert!(r.ledger.retrans_frames >= 5, "{ctx}: {}", r.ledger.retrans_frames);
        assert!(r.ledger.retrans_bytes > 0, "{ctx}");
        assert!(r.ledger.reconnects >= 1, "reset recorded no reconnect at {ctx}");
    }
}

/// Idempotency pin (the narrow dedup contract): duplicated frames in
/// both directions are applied exactly once — the equivalence proves
/// nothing was double-folded, and the retransmission count proves both
/// duplicates actually crossed the codec.
#[test]
fn inprocess_duplicate_frames_apply_once() {
    let plan = ChaosPlan::pinned(vec![
        spec(1, 0, FrameKind::Sweep, ChaosFault::Duplicate),
        spec(4, 0, FrameKind::Gather, ChaosFault::Duplicate),
        spec(FOLD_ITER, 1, FrameKind::FoldPart, ChaosFault::Duplicate),
    ]);
    let corpus = generate(&SynthSpec::tiny(47)).corpus;
    let cfg = cfg_for(2, PhiStorageMode::Replicated);
    let oracle = fit(&corpus, &params(), &cfg);
    let mut tp = InProcessTransport::new(2, 1).with_chaos(plan);
    let r = fit_dist(&corpus, &params(), &cfg, &mut tp).expect("duplicate chaos fit");
    assert_equiv(&r, &oracle, "inprocess duplicates");
    assert_eq!(r.ledger.chaos_faults, 3);
    // exactly the three duplicates, no other retransmissions
    assert_eq!(r.ledger.retrans_frames, 3);
    assert_eq!(r.ledger.reconnects, 0);
}

/// A seeded (statistical) schedule on the in-process carrier: the same
/// bitwise contract with faults drawn rather than pinned.
#[test]
fn inprocess_seeded_chaos_bitwise_equals_fit() {
    let corpus = generate(&SynthSpec::tiny(53)).corpus;
    let cfg = cfg_for(2, PhiStorageMode::Replicated);
    let oracle = fit(&corpus, &params(), &cfg);
    let mut tp = InProcessTransport::new(2, 1).with_chaos(ChaosPlan::seeded(909, 400));
    let r = fit_dist(&corpus, &params(), &cfg, &mut tp).expect("seeded chaos fit");
    assert_equiv(&r, &oracle, "inprocess seeded chaos");
    assert!(r.ledger.chaos_faults > 0, "permille 400 drew no faults");
}

/// The real-socket matrix: every fault type across Sweep requests,
/// Gather replies (the mid-reduce frame), the Batch state transfer and
/// the Fold exchange, against live `pobp-worker` processes at 2 and 3
/// workers in both storage modes. Send-direction faults are recovered
/// by the worker's session-reconnect; receive-direction faults by the
/// master's classify → rejoin → same-seq resend cycle.
#[test]
fn tcp_chaos_pinned_faults_bitwise_equal() {
    for &storage in &[PhiStorageMode::Replicated, PhiStorageMode::Sharded] {
        for &n in &[2usize, 3] {
            let plan = ChaosPlan::pinned(vec![
                // batch start: reset before the state transfer, and a
                // swallowed ack
                spec(0, 0, FrameKind::Batch, ChaosFault::Reset),
                spec(0, 1, FrameKind::BatchAck, ChaosFault::Drop),
                // sweep requests: corrupt, cut, hang, reset, duplicate
                spec(2, 0, FrameKind::Sweep, ChaosFault::FlipBit),
                spec(3, n - 1, FrameKind::Sweep, ChaosFault::Truncate),
                spec(4, 0, FrameKind::Sweep, ChaosFault::Drop),
                spec(5, 0, FrameKind::Sweep, ChaosFault::Reset),
                spec(6, 1, FrameKind::Sweep, ChaosFault::Duplicate),
                spec(7, 0, FrameKind::Sweep, ChaosFault::Delay { ms: 5 }),
                // gather replies (mid-reduce): corrupt, vanish, reset
                spec(2, 1, FrameKind::Gather, ChaosFault::FlipBit),
                spec(5, 1, FrameKind::Gather, ChaosFault::Drop),
                spec(6, 0, FrameKind::Gather, ChaosFault::Reset),
                // the fold exchange: corrupt request, torn reply
                spec(FOLD_ITER, 0, FrameKind::Fold, ChaosFault::FlipBit),
                spec(FOLD_ITER, n - 1, FrameKind::FoldPart, ChaosFault::Truncate),
            ]);
            let corpus = generate(&SynthSpec::tiny(59)).corpus;
            let cfg = cfg_for(n, storage);
            let oracle = fit(&corpus, &params(), &cfg);
            let mut tp = TcpTransport::spawn(n, TcpSpawnSpec { exe: worker_exe(), threads: 1 })
                .expect("spawn loopback workers")
                .with_io_timeout(Duration::from_secs(2))
                .with_chaos(plan.clone());
            let r = fit_dist(&corpus, &params(), &cfg, &mut tp).expect("tcp chaos fit");
            tp.shutdown().expect("clean worker shutdown");
            let ctx = format!("tcp pinned chaos n={n} {storage:?}");
            assert_equiv(&r, &oracle, &ctx);
            // a pinned spec can fire twice (the pipelined first send and
            // the forced resend after a rejoin are both attempt 0), so
            // the floor is the spec count, not an exact match
            assert!(
                r.ledger.chaos_faults >= plan.specs().len() as u64,
                "{ctx}: only {} faults fired",
                r.ledger.chaos_faults
            );
            assert!(r.ledger.retrans_frames > 0, "{ctx}: nothing retransmitted");
            assert!(r.ledger.retrans_bytes > 0, "{ctx}");
            assert!(r.ledger.reconnects > 0, "{ctx}: resets/corruptions recorded no reconnect");
            assert!(r.ledger.backoff_wait_secs > 0.0, "{ctx}: rejoin slept no backoff");
            assert_eq!(r.ledger.measured.len(), r.ledger.sync_count(), "{ctx}");
        }
    }
}

/// A seeded schedule over real sockets — the CI chaos-loopback shape:
/// statistically drawn faults on every frame of the run, still bitwise
/// equal to the undisturbed oracle.
#[test]
fn tcp_seeded_chaos_bitwise_equals_fit() {
    let corpus = generate(&SynthSpec::tiny(61)).corpus;
    let cfg = cfg_for(2, PhiStorageMode::Replicated);
    let oracle = fit(&corpus, &params(), &cfg);
    let mut tp = TcpTransport::spawn(2, TcpSpawnSpec { exe: worker_exe(), threads: 1 })
        .expect("spawn loopback workers")
        .with_io_timeout(Duration::from_secs(2))
        .with_chaos(ChaosPlan::seeded(1337, 150));
    let r = fit_dist(&corpus, &params(), &cfg, &mut tp).expect("tcp seeded chaos fit");
    tp.shutdown().expect("clean worker shutdown");
    assert_equiv(&r, &oracle, "tcp seeded chaos");
    assert!(r.ledger.chaos_faults > 0, "permille 150 drew no faults");
}
