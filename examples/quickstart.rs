//! Quickstart — the full three-layer stack in one minute:
//!
//! 1. generate a small synthetic corpus (LDA + Zipf, §Substitutions),
//! 2. train online BP where **every sweep executes the AOT-compiled XLA
//!    artifact** (L2 JAX graph embedding the L1 Pallas kernel) through
//!    PJRT from Rust — no Python at run time,
//! 3. evaluate predictive perplexity (Eq. 20) and print topics,
//! 4. re-train with the native engine and check both paths agree.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use std::path::PathBuf;

use pobp::corpus::split_tokens;
use pobp::engine::traits::LdaParams;
use pobp::eval::perplexity::predictive_perplexity;
use pobp::repro::{run_algo, Algo, RunOpts};
use pobp::runtime::xla_engine::{fit_obp_xla, XlaObpConfig};
use pobp::synth::{generate, SynthSpec};
use pobp::util::timer::fmt_secs;

fn main() -> anyhow::Result<()> {
    let artifact_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        artifact_dir.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    // 1. corpus (vocab must fit the compiled artifact: W <= 512, K = 50)
    let spec = SynthSpec {
        name: "quickstart".into(),
        docs: 256,
        vocab: 512,
        topics: 10,
        mean_doc_len: 60.0,
        zipf_s: 1.0,
        beta_gen: 0.05,
        alpha_gen: 0.08,
        seed: 7,
    };
    let corpus = generate(&spec).corpus;
    println!(
        "corpus: D={} W={} NNZ={} tokens={}",
        corpus.docs(), corpus.w, corpus.nnz(), corpus.tokens()
    );
    let k = 50;
    let params = LdaParams::paper(k);
    let split = split_tokens(&corpus, 0.2, 7);

    // 2. train through the XLA artifact (L3 -> L2 -> L1)
    let r_xla = fit_obp_xla(
        &split.train,
        &params,
        &artifact_dir,
        &XlaObpConfig { max_iters: 25, ..Default::default() },
    )?;
    println!(
        "\nXLA path: {} sweeps in {} (model mass {:.0})",
        r_xla.history.len(),
        fmt_secs(r_xla.wall_secs),
        r_xla.model.mass()
    );

    // 3. evaluate + topics
    let perp_xla = predictive_perplexity(&r_xla.model, &split, &params, 20, 7);
    println!("predictive perplexity (Eq. 20): {perp_xla:.1} (uniform would be ~{})", corpus.w);
    println!("\ntop words per topic (first 5 topics):");
    for t in 0..5 {
        let words: Vec<String> = r_xla
            .model
            .top_words(t, 8)
            .into_iter()
            .map(|(w, _)| format!("w{w:03}"))
            .collect();
        println!("  topic {t}: {}", words.join(" "));
    }

    // 4. native engine on the same data — same contract, must agree
    let r_nat = run_algo(
        Algo::Obp,
        &split.train,
        &params,
        &RunOpts { max_batch_iters: 25, nnz_budget: usize::MAX, seed: 42, ..Default::default() },
    );
    let perp_nat = predictive_perplexity(&r_nat.model, &split, &params, 20, 7);
    println!(
        "\nnative path perplexity: {perp_nat:.1}  (XLA {perp_xla:.1}; same-contract check: {})",
        if (perp_nat.ln() - perp_xla.ln()).abs() < 0.15 { "OK" } else { "DIVERGED" }
    );
    anyhow::ensure!(
        (perp_nat.ln() - perp_xla.ln()).abs() < 0.15,
        "XLA and native paths diverged"
    );
    println!("\nquickstart OK");
    Ok(())
}
