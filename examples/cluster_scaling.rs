//! Cluster scaling study (the §3.2.2 analysis, live): sweep the number of
//! simulated processors N and watch the cost decomposition
//!
//!     total(N) = compute/N + comm(N)
//!
//! bend exactly as Eq. 16 predicts, with the optimal N* of Eq. 17 visible
//! as the minimum of the simulated total. Also contrasts POBP's
//! power-subset payloads against a full-matrix variant so the
//! communication savings (Eq. 6 vs Eq. 5) are directly visible.
//!
//! Run: `cargo run --release --example cluster_scaling`

use pobp::engine::traits::LdaParams;
use pobp::repro::{dataset, run_algo, Algo, RunOpts};
use pobp::sched::PowerParams;

fn main() {
    let k = 50;
    let corpus = dataset("nytimes", 1500, k, 9);
    let params = LdaParams::paper(k);
    println!(
        "corpus: D={} W={} NNZ={} tokens={}\n",
        corpus.docs(), corpus.w, corpus.nnz(), corpus.tokens()
    );

    println!("POBP (power subsets, λ_W=0.1):");
    println!("  N    compute_s     comm_s    total_s   payload_MB");
    let mut best = (0usize, f64::INFINITY);
    for &n in &[1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512] {
        let o = RunOpts { n_workers: n, ..Default::default() };
        let r = run_algo(Algo::Pobp, &corpus, &params, &o);
        let total = r.sim_secs();
        if total < best.1 {
            best = (n, total);
        }
        println!(
            "{n:>4} {:>11.4} {:>10.4} {:>10.4} {:>12.2}",
            r.ledger.compute_secs,
            r.ledger.comm_secs,
            total,
            r.ledger.payload_bytes_total() as f64 / 1e6,
        );
    }
    println!("  -> optimal N* ≈ {} (Eq. 17: sqrt(compute/comm ratio))\n", best.0);

    println!("ablation: same run with full-matrix sync (λ_W = 1):");
    println!("  N    compute_s     comm_s    total_s   payload_MB");
    for &n in &[1usize, 8, 64, 256] {
        let o = RunOpts {
            n_workers: n,
            power: PowerParams::full(),
            ..Default::default()
        };
        let r = run_algo(Algo::PobpFull, &corpus, &params, &o);
        println!(
            "{n:>4} {:>11.4} {:>10.4} {:>10.4} {:>12.2}",
            r.ledger.compute_secs,
            r.ledger.comm_secs,
            r.sim_secs(),
            r.ledger.payload_bytes_total() as f64 / 1e6,
        );
    }
    println!("\nthe full-sync variant hits the communication wall at much smaller N —");
    println!("that wall is what the paper's power words/topics remove.");
}
