//! Life-long topic modeling on a news stream (§3.2: "When M → ∞, POBP can
//! be viewed as a life-long or never-ending topic modeling algorithm").
//!
//! A synthetic "news wire" arrives in daily batches whose topic mixture
//! drifts over time. POBP consumes each batch once with constant memory
//! (the paper's Table 5 property) while the model keeps absorbing new
//! vocabulary usage. The example prints, per day: residual at
//! convergence, perplexity on that day's held-out tokens, communicated
//! bytes, and process RSS — the RSS staying flat is the online-memory
//! claim, observable directly.

use pobp::coordinator::{fit, PobpConfig};
use pobp::corpus::{split_tokens, Csr};
use pobp::engine::traits::{LdaParams, Model};
use pobp::eval::perplexity::predictive_perplexity;
use pobp::sched::PowerParams;
use pobp::synth::{generate, SynthSpec};
use pobp::util::mem::rss_bytes;
use pobp::util::rng::Rng;

/// One "day" of news: the generator's topic prior drifts with the day.
fn day_batch(day: usize, docs: usize) -> Csr {
    let spec = SynthSpec {
        name: format!("day{day}"),
        docs,
        vocab: 600,
        topics: 12,
        mean_doc_len: 80.0,
        zipf_s: 1.0,
        // drift: alternate between "politics-heavy" and "sports-heavy"
        // weeks by shifting the Dirichlet concentration
        alpha_gen: 0.05 + 0.04 * ((day / 7) % 2) as f64,
        beta_gen: 0.04,
        seed: 1000 + day as u64,
    };
    generate(&spec).corpus
}

fn main() {
    let k = 24;
    let params = LdaParams::paper(k);
    let days = 12;
    let mut model: Option<Model> = None;
    let mut rng = Rng::new(3);

    println!("day  batches  resid@end  perplexity  comm_KB  rss_MB");
    let mut total_wire = 0u64;
    for day in 0..days {
        let batch = day_batch(day, 120);
        let split = split_tokens(&batch, 0.2, rng.next_u64());

        // warm-start phi from the accumulated model: POBP's Eq. 11 SGD —
        // previous sufficient statistics stay; the new batch adds its
        // gradient. We emulate the stream by folding yesterday's phi in
        // through a corpus-level accumulator.
        let cfg = PobpConfig {
            n_workers: 4,
            nnz_budget: 20_000,
            power: PowerParams::paper_default(),
            max_iters: 30,
            seed: 100 + day as u64,
            ..Default::default()
        };
        let r = fit(&split.train, &params, &cfg);
        let mut phi = r.model.phi_wk.clone();
        if let Some(prev) = &model {
            for (p, &q) in phi.iter_mut().zip(&prev.phi_wk) {
                *p += q; // accumulate sufficient statistics across days
            }
        }
        let day_model = Model { k, w: batch.w, phi_wk: phi };

        let perp = predictive_perplexity(&day_model, &split, &params, 15, day as u64);
        let last_resid = r
            .history
            .last()
            .map(|s| s.residual_per_token)
            .unwrap_or(f64::NAN);
        total_wire += r.ledger.wire_bytes;
        println!(
            "{day:>3}  {:>7}  {:>9.4}  {:>10.1}  {:>7}  {:>6}",
            r.history.iter().map(|s| s.batch).max().map(|m| m + 1).unwrap_or(0),
            last_resid,
            perp,
            r.ledger.wire_bytes / 1024,
            rss_bytes() / (1 << 20),
        );
        model = Some(day_model);
    }
    println!("\ntotal wire traffic across {days} days: {} MB", total_wire / (1 << 20));
    println!("note the flat rss_MB column: constant memory in the stream length (Table 5 property)");
}
