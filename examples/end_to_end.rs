//! End-to-end validation driver (EXPERIMENTS.md §End-to-end): the full
//! system on a real small workload, proving all layers compose.
//!
//! Pipeline:
//!   1. generate the enron-sim corpus (Table-3 statistics ÷100), write it
//!      to disk in UCI bag-of-words format, read it back (corpus I/O),
//!   2. truncate the vocabulary like the paper's preprocessing (§4),
//!   3. 80/20 split, then train THREE systems on identical data:
//!      POBP (N=16, power selection), PFGS (N=16), PVB (N=16),
//!      plus OBP-via-XLA for the three-layer path,
//!   4. report the paper's headline metrics: predictive perplexity,
//!      simulated training/communication time, wire bytes, memory,
//!      topic coherence — and check the expected orderings hold.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`

use std::path::PathBuf;

use pobp::corpus::{bow, split_tokens, vocab};
use pobp::engine::traits::LdaParams;
use pobp::eval::coherence::mean_coherence;
use pobp::eval::perplexity::predictive_perplexity;
use pobp::repro::{run_algo, Algo, RunOpts};
use pobp::synth::{generate, SynthSpec, TABLE3};
use pobp::util::mem::rss_bytes;
use pobp::util::timer::fmt_secs;

fn main() -> anyhow::Result<()> {
    // K = 100: the paper's accuracy gap grows with K (Table 4); at
    // bench-scale K = 50 POBP and PFGS are statistically tied, at K = 100
    // POBP wins outright (see results/table4_gap.csv).
    let k = 100;
    println!("=== POBP end-to-end driver (enron-sim, K={k}, N=16) ===\n");

    // 1. generate + roundtrip through the UCI format
    let spec = SynthSpec::from_table(&TABLE3[0], 100, k, 42);
    let gen = generate(&spec);
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("data");
    bow::write_uci_pair(&dir, "enron-sim", &gen.corpus, &pobp::corpus::Vocab::synthetic(gen.corpus.w))?;
    let corpus_raw = bow::read_uci(&dir.join("docword.enron-sim.txt"))?;
    println!(
        "corpus (disk roundtrip): D={} W={} NNZ={} tokens={}",
        corpus_raw.docs(), corpus_raw.w, corpus_raw.nnz(), corpus_raw.tokens()
    );

    // 2. vocabulary truncation (paper §4 preprocessing)
    let v = pobp::corpus::Vocab::synthetic(corpus_raw.w);
    let trunc = vocab::truncate_by_tokens(&corpus_raw, &v, 1500);
    println!(
        "truncated vocabulary to {} words, token retention {:.1}% (paper kept >40%)\n",
        trunc.kept_words,
        trunc.token_retention * 100.0
    );
    let corpus = trunc.corpus;
    let params = LdaParams::paper(k);
    let split = split_tokens(&corpus, 0.2, 42);

    // 3. train the three systems. Calibration notes (EXPERIMENTS.md):
    //    λ_K·K = k/3 corresponds to the paper's "keep each word's
    //    plausible topic set" reading of λ_K·K = 50 at K = 2000;
    //    the network model is bandwidth-scaled so per-sync times sit in
    //    the paper's regime (NetModel::infiniband_for_scale).
    let o = RunOpts {
        n_workers: 16,
        iters: 80,
        max_batch_iters: 400,
        power: pobp::sched::PowerParams { lambda_w: 0.1, lambda_k_times_k: k / 3 },
        net: pobp::comm::NetModel::infiniband_for_scale(k, corpus.w),
        ..Default::default()
    };
    println!("{:8} {:>10} {:>11} {:>10} {:>9} {:>10} {:>9}", "algo", "perplexity", "sim_total_s", "comm_s", "wire_MB", "coherence", "rss_MB");
    let mut rows = Vec::new();
    for algo in [Algo::Pobp, Algo::Pfgs, Algo::Pvb] {
        let r = run_algo(algo, &split.train, &params, &o);
        let perp = predictive_perplexity(&r.model, &split, &params, 20, 42);
        let coh = mean_coherence(&r.model, &split.train, 8);
        println!(
            "{:8} {:>10.1} {:>11} {:>10} {:>9.1} {:>10.2} {:>9}",
            algo.name(),
            perp,
            fmt_secs(r.sim_secs()),
            fmt_secs(r.ledger.comm_secs),
            r.ledger.wire_bytes as f64 / 1e6,
            coh,
            rss_bytes() / (1 << 20),
        );
        rows.push((algo, perp, r.sim_secs(), r.ledger.comm_secs));
    }

    // 3b. the three-layer XLA path on a compatible sub-corpus
    let artifact_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifact_dir.join("manifest.json").exists() {
        let small = vocab::truncate_by_tokens(&corpus, &pobp::corpus::Vocab::default(), 512);
        let r = pobp::runtime::xla_engine::fit_obp_xla(
            &small.corpus,
            &params,
            &artifact_dir,
            &Default::default(),
        )?;
        let s2 = split_tokens(&small.corpus, 0.2, 43);
        let perp = predictive_perplexity(&r.model, &s2, &params, 20, 43);
        println!(
            "{:8} {:>10.1} {:>11}   (three-layer PJRT path, 512-word vocab)",
            "obp-xla", perp, fmt_secs(r.wall_secs)
        );
    } else {
        println!("obp-xla skipped (run `make artifacts`)");
    }

    // 4. headline checks (the paper's qualitative claims)
    let (p_pobp, t_pobp, c_pobp) = {
        let r = &rows[0];
        (r.1, r.2, r.3)
    };
    let (p_pfgs, t_pfgs, c_pfgs) = {
        let r = &rows[1];
        (r.1, r.2, r.3)
    };
    let (p_pvb, ..) = { (rows[2].1, ()) };
    // Bounds note: the paper reports 20–65% perplexity gaps and 5–20%
    // comm ratios at K ∈ {500..2000} on the real corpora; at bench scale
    // (K = 50, 100× smaller corpus) the same mechanisms yield parity-or-
    // better accuracy and a 15–40% comm ratio — see EXPERIMENTS.md for
    // the scale analysis. The checks below assert the paper's *ordering*
    // with bench-scale margins.
    println!("\nheadline checks:");
    let checks = [
        ("POBP more accurate than PFGS", p_pobp < p_pfgs),
        ("POBP more accurate than PVB", p_pobp < p_pvb),
        ("POBP faster than PFGS (sim)", t_pobp < t_pfgs),
        ("POBP comm < 40% of PFGS comm", c_pobp < 0.4 * c_pfgs),
    ];
    let mut ok = true;
    for (name, pass) in checks {
        println!("  [{}] {name}", if pass { "PASS" } else { "FAIL" });
        ok &= pass;
    }
    anyhow::ensure!(ok, "an end-to-end headline check failed");
    println!("\nend_to_end OK");
    Ok(())
}
