//! Fig. 7 — predictive perplexity and training time as a function of the
//! power ratios λ_W and λ_K·K on ENRON with 12 processors.
//!
//! Paper setting: ENRON, K = 500, λ_W ∈ {0.025..1}, λ_K·K ∈ {30..70, 500}.
//! Here: enron-sim, K = 50, λ_K·K scaled to {3..7, 50} (same fractions of
//! K). Expected shape: training time falls as either ratio falls;
//! perplexity stays ≈flat until λ_W drops below ~0.1, then degrades.

#[path = "common/mod.rs"]
mod common;

use pobp::corpus::split_tokens;
use pobp::eval::perplexity::predictive_perplexity;
use pobp::metrics::{results_dir, sig, Table};
use pobp::repro::{run_algo, Algo, RunOpts};
use pobp::sched::PowerParams;

fn main() {
    common::banner("Fig 7", "perplexity + time vs λ_W and λ_K·K", "enron-sim, K=50, N=12");
    let k = 50;
    let corpus = common::corpus("enron", k, 7);
    let params = common::params(k);
    let split = split_tokens(&corpus, 0.2, 7);

    let run = |lambda_w: f64, lkk: usize| -> (f64, f64, f64) {
        let o = RunOpts {
            n_workers: 12,
            power: PowerParams { lambda_w, lambda_k_times_k: lkk },
            max_batch_iters: 40,
            ..Default::default()
        };
        let r = run_algo(Algo::Pobp, &split.train, &params, &o);
        let perp = predictive_perplexity(&r.model, &split, &params, 20, 7);
        (perp, r.wall_secs, r.sim_secs())
    };

    // (A) vary λ_W with all topics
    let mut ta = Table::new("fig7a_lambda_w", &["lambda_w", "perplexity", "wall_secs", "sim_secs"]);
    for &lw in &[0.025, 0.05, 0.1, 0.2, 0.4, 1.0] {
        let (p, wall, sim) = run(lw, k);
        ta.row(&[lw.to_string(), sig(p), sig(wall), sig(sim)]);
    }
    println!("{}", ta.render());
    ta.save(&results_dir()).unwrap();

    // (B) vary λ_K·K with all words (paper's 30..70 out of 500 → 3..7 of 50)
    let mut tb = Table::new("fig7b_lambda_k", &["lambda_k_times_k", "perplexity", "wall_secs", "sim_secs"]);
    for &lkk in &[3usize, 4, 5, 6, 7, k] {
        let (p, wall, sim) = run(1.0, lkk);
        tb.row(&[lkk.to_string(), sig(p), sig(wall), sig(sim)]);
    }
    println!("{}", tb.render());
    tb.save(&results_dir()).unwrap();

    // (C) combinations around the paper's recommended {λ_W=0.1, λ_K·K=50/500}
    let mut tc = Table::new("fig7c_combo", &["lambda_w", "lambda_k_times_k", "perplexity", "wall_secs", "sim_secs"]);
    for &(lw, lkk) in &[(1.0, k), (0.2, 7), (0.1, 5), (0.1, 7), (0.05, 5)] {
        let (p, wall, sim) = run(lw, lkk);
        tc.row(&[lw.to_string(), lkk.to_string(), sig(p), sig(wall), sig(sim)]);
    }
    println!("{}", tc.render());
    tc.save(&results_dir()).unwrap();
    println!("saved fig7a/b/c csv files");
}
