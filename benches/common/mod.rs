//! Shared bench prelude: scaled dataset definitions and run defaults used
//! by every figure/table target. The scale-downs (documented per bench)
//! keep each target under ~a minute on a laptop while preserving the
//! corpus *shape* statistics (tokens/doc, Zipf marginal, W/D flavour) that
//! the paper's qualitative results depend on. `POBP_BENCH_SCALE=full`
//! grows the corpora ~10×.

#![allow(dead_code)]

use pobp::corpus::Csr;
use pobp::engine::traits::LdaParams;
use pobp::repro::{dataset, RunOpts};
use pobp::sched::PowerParams;

/// The three "web-scale" corpora of §4, scaled.
pub const BIG3: [&str; 3] = ["nytimes", "wikipedia", "pubmed"];

/// Scaled topic counts standing in for the paper's K ∈ {500, 1000, 2000}.
pub const K_SWEEP: [usize; 3] = [25, 50, 100];

pub fn full() -> bool {
    std::env::var("POBP_BENCH_SCALE").map(|v| v == "full").unwrap_or(false)
}

/// Document-count divisor per corpus, tuned so each scaled corpus lands
/// around 300–600 documents (3–10× more with POBP_BENCH_SCALE=full).
pub fn scale_of(name: &str) -> usize {
    let base = match name {
        "enron" => 100,
        "nytimes" => 1000,
        "wikipedia" => 10_000,
        "pubmed" => 20_000,
        _ => 1,
    };
    if full() {
        base / 10
    } else {
        base
    }
}

pub fn corpus(name: &str, k: usize, seed: u64) -> Csr {
    dataset(name, scale_of(name), k, seed)
}

/// Paper-default run options at bench scale: N = 256 simulated processors
/// for the accuracy/comm figures, λ_W = 0.1, λ_K·K scaled as 50·K/2000
/// of the paper's 2000-topic setting but never below 5.
pub fn opts(n_workers: usize, k: usize) -> RunOpts {
    RunOpts {
        n_workers,
        iters: if full() { 200 } else { 60 },
        max_batch_iters: 400,
        nnz_budget: 45_000,
        // The paper's λ_K·K = 50 at K = 500–2000 keeps each word's full
        // plausible topic set (λ_K as low as 0.025 works *because* K is
        // large). At bench-scale K (25–100) the same reading needs
        // λ_K ≈ 0.3; tighter selection visibly degrades accuracy — the
        // Fig. 7B trade-off, measured in fig7_lambda_sweep.
        power: PowerParams { lambda_w: 0.1, lambda_k_times_k: (k / 3).max(8) },
        // fixed reference scale (K=50, W=2000) across every sweep point so
        // K/dataset dependence stays visible — see NetModel docs
        net: pobp::comm::NetModel::infiniband_for_scale(50, 2000),
        ..Default::default()
    }
}

pub fn params(k: usize) -> LdaParams {
    LdaParams::paper(k)
}

/// Banner every bench prints so the output is self-describing.
pub fn banner(fig: &str, what: &str, scale_note: &str) {
    println!("== {fig}: {what}");
    println!("   scale: {scale_note}");
    println!(
        "   (set POBP_BENCH_SCALE=full for ~10x larger corpora)\n"
    );
}
