//! Microbenchmarks of the L3 hot paths (criterion substitute): the sparse
//! BP sweep, the Gibbs samplers, the power selection partial sort, and
//! the allreduce. These are the §Perf numbers in EXPERIMENTS.md.

#[path = "common/mod.rs"]
mod common;

use std::time::Instant;

use pobp::comm::{reduce_chunked, reduce_sum_into, Cluster};
use pobp::engine::bp::{Selection, ShardBp};
use pobp::engine::fgs::FastGs;
use pobp::engine::gibbs::{GibbsShard, PlainGs};
use pobp::engine::sgs::SparseGs;
use pobp::metrics::sig;
use pobp::sched::{select_power, PowerParams};
use pobp::util::rng::Rng;

fn bench<F: FnMut()>(name: &str, iters: usize, work_items: f64, mut f: F) {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "{name:40} {:>12}/iter   {:>14} items/s",
        format!("{:.3}ms", per * 1e3),
        sig(work_items / per)
    );
}

fn main() {
    common::banner("microbench", "hot-path throughput", "enron-sim, K=50");
    let k = 50;
    let corpus = common::corpus("enron", k, 1);
    let params = common::params(k);
    println!(
        "corpus: D={} W={} NNZ={} tokens={}\n",
        corpus.docs(), corpus.w, corpus.nnz(), corpus.tokens()
    );

    // --- BP sweep (the POBP worker inner loop) ---
    let mut rng = Rng::new(1);
    let mut shard = ShardBp::init(corpus.clone(), k, &mut rng);
    let sel = Selection::full(corpus.w);
    let updates = corpus.nnz() as f64 * k as f64;
    // frozen phi snapshot: timing measures the sweep itself, not the
    // leader's phi rebuild (that cost is the allreduce bench below)
    let phi = shard.dphi.clone();
    let mut tot = vec![0f32; k];
    for row in phi.chunks_exact(k) {
        for (t, &v) in row.iter().enumerate() {
            tot[t] += v;
        }
    }
    bench("bp sweep (full, token-topic updates)", 10, updates, || {
        shard.clear_selected_residuals(&sel);
        shard.sweep(&phi, &tot, &sel, &params, true);
    });

    // power-subset sweep (same schedule the coordinator runs at t >= 2);
    // work items = active entries x selected topics, the true flop count
    let ps = select_power(&shard.r, corpus.w, k, &PowerParams::paper_default());
    let sel_p = Selection::from_power(&ps, corpus.w);
    let active_entries: usize = (0..corpus.w)
        .filter(|&wi| sel_p.word_sel[wi])
        .map(|wi| {
            (0..corpus.docs())
                .map(|d| usize::from(corpus.row(d).0.binary_search(&(wi as u32)).is_ok()))
                .sum::<usize>()
        })
        .sum();
    let sub_updates = (active_entries * sel_p.topics_of(ps.words[0] as usize).map(|t| t.len()).unwrap_or(k)) as f64;
    bench("bp sweep (power subset, doc-order)", 10, sub_updates, || {
        shard.clear_selected_residuals(&sel_p);
        shard.sweep(&phi, &tot, &sel_p, &params, true);
    });
    bench("bp sweep (power subset, inverted idx)", 10, sub_updates, || {
        shard.clear_selected_residuals(&sel_p);
        shard.sweep_selected(&phi, &tot, &sel_p, &params, true);
    });

    // --- Gibbs samplers (tokens/s) ---
    let tokens = corpus.tokens();
    let mut gshard = GibbsShard::init(&corpus, k, &mut rng);
    let mut plain = PlainGs::new(k);
    let mut grng = Rng::new(2);
    bench("gibbs sweep (plain GS)", 5, tokens, || {
        gshard.sweep(&mut plain, &params, &mut grng);
    });
    let mut sparse = SparseGs::new(k);
    bench("gibbs sweep (SparseLDA)", 5, tokens, || {
        gshard.sweep(&mut sparse, &params, &mut grng);
    });
    let mut fast = FastGs::new(k);
    bench("gibbs sweep (FastLDA)", 5, tokens, || {
        gshard.sweep(&mut fast, &params, &mut grng);
    });

    // --- power selection (per coordinator iteration) ---
    let r = shard.r.clone();
    bench("power selection (partial sort W + topics)", 50, (corpus.w * k) as f64, || {
        let _ = select_power(&r, corpus.w, k, &PowerParams::paper_default());
    });

    // --- leader-side allreduce, before/after: the pre-refactor serial
    //     leader loop vs the chunked parallel reduction on the cluster
    //     thread pool (comm::allreduce). Same bitwise result; the
    //     parallel path buys leader wall-clock on multi-core hosts. ---
    let nw = 8;
    let cluster = Cluster::new(nw, 0);
    let partials: Vec<Vec<f32>> = (0..nw).map(|i| vec![i as f32; corpus.w * k]).collect();
    let parts: Vec<&[f32]> = partials.iter().map(|p| p.as_slice()).collect();
    let mut g = vec![0f32; corpus.w * k];
    let dense_items = (corpus.w * k * nw) as f64;
    bench("allreduce dense serial (old leader loop)", 20, dense_items, || {
        g.fill(0.0);
        reduce_sum_into(&mut g, &partials);
        std::hint::black_box(&g);
    });
    bench("allreduce dense parallel (chunked)", 20, dense_items, || {
        reduce_chunked(&cluster, None, &parts, &mut g);
        std::hint::black_box(&g);
    });

    // subset variant at the paper's power-selection density: both sides
    // reduce the same packed plan-order buffers, so the comparison
    // isolates the chunked reduction itself
    let idx = select_power(&r, corpus.w, k, &PowerParams::paper_default()).flat_indices(k);
    let sub_partials: Vec<Vec<f32>> = (0..nw).map(|i| vec![i as f32; idx.len()]).collect();
    let sub_parts: Vec<&[f32]> = sub_partials.iter().map(|p| p.as_slice()).collect();
    let mut red = vec![0f32; idx.len()];
    let sub_items = (idx.len() * nw) as f64;
    bench("allreduce subset serial (packed)", 200, sub_items, || {
        red.fill(0.0);
        reduce_sum_into(&mut red, &sub_partials);
        std::hint::black_box(&red);
    });
    bench("allreduce subset parallel (chunked)", 200, sub_items, || {
        reduce_chunked(&cluster, None, &sub_parts, &mut red);
        std::hint::black_box(&red);
    });
}
