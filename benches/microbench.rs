//! Microbenchmarks of the L3 hot paths (criterion substitute): the sparse
//! BP sweep (serial reference vs fused vs doc-parallel), the Gibbs
//! samplers, the power selection partial sort, and the allreduce
//! (serial reference vs retired leader-pool vs owner-sliced
//! reduce-scatter). These are the §Perf numbers in EXPERIMENTS.md;
//! alongside the human table the run emits `BENCH_microbench.json`
//! (name → items/s, plus the measured POBP overlap efficiency) so the
//! perf trajectory is machine-trackable across PRs. The Contract 7 rows
//! (scalar vs wide kernel, pinned vs floating pool, spawn-threshold
//! grains) force each kernel via `simd::force_kernel` so the scalar
//! baseline stays honest on a `--features simd` build, and report a
//! median-over-min timing-variance column for the noise-sensitive pairs.
//!
//! `--smoke` (or `--test`) runs every row once on the same corpus
//! without writing the JSON — the CI quick pass that keeps the bench
//! *executing*, not just compiling. The smoke pass includes the
//! kill-and-recover matrix (Contract 6): a training run is killed at
//! each sync phase in both storage modes and must recover bitwise.

#[path = "common/mod.rs"]
mod common;

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Mutex;
use std::time::Instant;

use pobp::comm::allreduce::{
    allreduce_step, allreduce_step_overlap, allreduce_step_overlap_rounds,
    allreduce_step_pool, allreduce_step_sharded, serial_reference_step, GlobalState,
    OwnerSlices, ReducePlan, ReduceSource, SerialState, ShardedState, SyncScratch,
};
use pobp::comm::transport::InProcessTransport;
use pobp::comm::{Cluster, NetModel};
use pobp::coordinator::{fit, fit_dist, fit_resilient, PobpConfig, ResilienceConfig};
use pobp::engine::bp::{Selection, ShardBp};
use pobp::engine::simd::{self, KernelKind};
use pobp::fault::{ChaosPlan, FaultPlan, SyncPhase};
use pobp::storage::checkpoint::list_checkpoints;
use pobp::storage::{Checkpoint, PhiShard, PhiStorageMode};
use pobp::util::mem::MemModel;
use pobp::engine::fgs::FastGs;
use pobp::engine::gibbs::{GibbsShard, PlainGs};
use pobp::engine::sgs::SparseGs;
use pobp::engine::snapshot::{clone_rebuild, PhiSnapshot};
use pobp::metrics::sig;
use pobp::sched::{select_power, DocSchedule, PowerParams};
use pobp::util::json::Json;
use pobp::util::partial_sort::top_k_desc;
use pobp::util::rng::Rng;

/// One bench row: mean-based items/s (the recorded number, unchanged
/// semantics) plus the per-iteration min and median so noise-sensitive
/// rows can report a timing-variance column (median/min ≈ 1.0 on a quiet
/// host; large values mean the row's ratio rows are untrustworthy).
struct Row {
    ips: f64,
    min_secs: f64,
    med_secs: f64,
}

impl Row {
    /// median-over-min timing variance (1.0 = perfectly quiet).
    fn variance(&self) -> f64 {
        if self.min_secs > 0.0 {
            self.med_secs / self.min_secs
        } else {
            0.0
        }
    }
}

fn bench<F: FnMut()>(
    recs: &mut Vec<(String, f64)>,
    name: &str,
    iters: usize,
    work_items: f64,
    mut f: F,
) -> Row {
    // warmup
    f();
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let per = times.iter().sum::<f64>() / iters as f64;
    times.sort_by(f64::total_cmp);
    let ips = work_items / per;
    println!(
        "{name:42} {:>12}/iter   {:>14} items/s",
        format!("{:.3}ms", per * 1e3),
        sig(ips)
    );
    recs.push((name.to_string(), ips));
    Row { ips, min_secs: times[0], med_secs: times[times.len() / 2] }
}

fn main() {
    // CI quick pass: one timed iteration per row, no JSON overwrite
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "--test");
    let it = |n: usize| if smoke { 1 } else { n };
    common::banner("microbench", "hot-path throughput", "enron-sim, K=50");
    if smoke {
        println!("   (--smoke: single-iteration rows, JSON not written)\n");
    }
    let k = 50;
    let corpus = common::corpus("enron", k, 1);
    let params = common::params(k);
    println!(
        "corpus: D={} W={} NNZ={} tokens={}\n",
        corpus.docs(), corpus.w, corpus.nnz(), corpus.tokens()
    );
    let mut recs: Vec<(String, f64)> = Vec::new();

    // --- BP sweep (the POBP worker inner loop): the pre-fusion serial
    //     kernel (kept as the equivalence oracle), the fused serial
    //     kernel, and the doc-parallel engine on the full OS-thread
    //     pool (the N = 1 coordinator configuration) ---
    let pool = Cluster::new(1, 0);
    let mut rng = Rng::new(1);
    let mut shard = ShardBp::init(corpus.clone(), k, &mut rng);
    let sel = Selection::full(corpus.w);
    let updates = corpus.nnz() as f64 * k as f64;
    // frozen phi snapshot: timing measures the sweep itself, not the
    // leader's phi rebuild (that cost is the allreduce bench below)
    let phi = shard.dphi.clone();
    let mut tot = vec![0f32; k];
    for row in phi.chunks_exact(k) {
        for (t, &v) in row.iter().enumerate() {
            tot[t] += v;
        }
    }
    bench(&mut recs, "bp sweep (full, serial reference)", it(10), updates, || {
        shard.clear_selected_residuals(&sel);
        shard.sweep_reference(&phi, &tot, &sel, &params, true);
    });
    // Contract 7 kernel pair: force each kernel explicitly so a
    // `--features simd` build still reports an honest scalar baseline.
    // Both kernels are bitwise-equal (tests/kernel_equiv.rs), so the
    // timed work is identical; on a scalar build the forced wide kernel
    // resolves to scalar and the ratio reads ~1.0x.
    simd::force_kernel(Some(KernelKind::Scalar));
    let row_fus = bench(&mut recs, "bp sweep (full, fused serial)", it(10), updates, || {
        shard.clear_selected_residuals(&sel);
        shard.sweep(&phi, &tot, &sel, &params, true);
    });
    simd::force_kernel(Some(KernelKind::Wide));
    let row_wid = bench(&mut recs, "bp sweep (full, simd serial)", it(10), updates, || {
        shard.clear_selected_residuals(&sel);
        shard.sweep(&phi, &tot, &sel, &params, true);
    });
    simd::force_kernel(None);
    bench(&mut recs, "bp sweep (full, doc-parallel)", it(10), updates, || {
        shard.sweep_parallel(&pool, 0, &phi, &tot, &sel, &params, true);
    });
    // the same pool with best-effort core pinning (with_pinning): a pure
    // performance hint — on refused affinity or few-core hosts this row
    // reads ~1.0x vs floating, which is the honest answer
    let pool_pinned = Cluster::new(1, 0).with_pinning(true);
    bench(&mut recs, "bp sweep (full, doc-parallel pinned)", it(10), updates, || {
        shard.sweep_parallel(&pool_pinned, 0, &phi, &tot, &sel, &params, true);
    });

    // power-subset sweep (same schedule the coordinator runs at t >= 2);
    // work items = Σ_selected-words entries(w) × topics(w) — the true
    // per-pair update count, from the shard's inverted index instead of
    // the old O(W·D·log nnz) binary-search scan (which also multiplied
    // every word by the *first* selected word's topic count)
    let ps = select_power(&shard.r, corpus.w, k, &PowerParams::paper_default());
    let sel_p = Selection::from_power(&ps, corpus.w);
    let active_entries: usize = (0..corpus.w)
        .filter(|&wi| sel_p.word_sel[wi])
        .map(|wi| shard.word_entries(wi))
        .sum();
    let sub_updates: f64 = (0..corpus.w)
        .filter(|&wi| sel_p.word_sel[wi])
        .map(|wi| {
            let topics = sel_p.topics_of(wi).map(|t| t.len()).unwrap_or(k);
            (shard.word_entries(wi) * topics) as f64
        })
        .sum();
    println!(
        "power subset: {} active entries, {} pair updates",
        active_entries, sub_updates
    );
    simd::force_kernel(Some(KernelKind::Scalar));
    let row_sub = bench(&mut recs, "bp sweep (power subset, doc-order)", it(10), sub_updates, || {
        shard.clear_selected_residuals(&sel_p);
        shard.sweep(&phi, &tot, &sel_p, &params, true);
    });
    let row_sub_sc = bench(&mut recs, "bp sweep (power subset, inverted idx)", it(10), sub_updates, || {
        shard.clear_selected_residuals(&sel_p);
        shard.sweep_selected(&phi, &tot, &sel_p, &params, true);
    });
    // the packed-gather arm under the wide kernel (the subset path
    // Contract 7 vectorizes); compared against the forced-scalar
    // inverted-idx row above — same sweep, same plan, kernel-only delta
    simd::force_kernel(Some(KernelKind::Wide));
    let row_sub_wid = bench(&mut recs, "bp sweep (power subset, simd)", it(10), sub_updates, || {
        shard.clear_selected_residuals(&sel_p);
        shard.sweep_selected(&phi, &tot, &sel_p, &params, true);
    });
    simd::force_kernel(None);
    bench(&mut recs, "bp sweep (power subset, doc-parallel)", it(10), sub_updates, || {
        shard.sweep_parallel(&pool, 0, &phi, &tot, &sel_p, &params, true);
    });

    // --- ABP φ̂ publish: the retired per-iteration clone + f64 totals
    //     rebuild (always O(W·K)) vs the incremental PhiSnapshot publish
    //     (O(selected pairs + W) on power subsets) — the per-iteration
    //     leader overhead the snapshot engine removes. Items = W·K for
    //     every row (one logical view refresh), so the speedup is the
    //     plain time ratio. ---
    let pub_items = (corpus.w * k) as f64;
    // clone_rebuild takes no selection (that is the point — its cost is
    // O(W·K) regardless), so it is measured once and recorded under both
    // selection labels as the baseline of the matching incremental rows
    bench(&mut recs, "phi publish (clone+rebuild, full)", it(50), pub_items, || {
        std::hint::black_box(clone_rebuild(&shard.dphi, k));
    });
    let clone_ips = recs.last().map(|&(_, v)| v).unwrap_or(0.0);
    recs.push(("phi publish (clone+rebuild, power subset)".to_string(), clone_ips));
    let mut snap = PhiSnapshot::new(&shard.dphi, k, 0);
    bench(&mut recs, "phi publish (incremental, full)", it(50), pub_items, || {
        snap.apply(&shard.dphi, &sel);
    });
    // the power-subset publish runs ABP's actual hot path: the
    // PowerSet's explicit word list, no W-wide bitmap scan
    bench(&mut recs, "phi publish (incremental, power subset)", it(200), pub_items, || {
        snap.apply_power(&shard.dphi, &ps);
    });

    // --- scheduled (ABP t >= 2) sweep: residual-top 30% of the docs,
    //     serial sweep_docs vs the permuted-block parallel path — the
    //     last sweep that used to be serial. The schedule comes from the
    //     per-doc residuals of the full parallel sweep above, like ABP's
    //     own loop; work items count only the scheduled docs' updates. ---
    let r_doc: Vec<f32> = shard.doc_residuals().iter().map(|&v| v as f32).collect();
    let active_docs = (corpus.docs() * 3).div_ceil(10).max(1);
    let scheduled = top_k_desc(&r_doc, active_docs);
    let ds = DocSchedule::build(&scheduled, |d| corpus.row_range(d).len());
    println!(
        "scheduled sweep: {} docs, {} nnz, {} blocks",
        ds.len(), ds.nnz(), ds.blocks()
    );
    let sched_updates = ds.nnz() as f64 * k as f64;
    bench(&mut recs, "bp sweep (scheduled, serial sweep_docs)", it(10), sched_updates, || {
        shard.clear_selected_residuals(&sel);
        shard.sweep_docs(&scheduled, &phi, &tot, &sel, &params, true);
    });
    bench(&mut recs, "bp sweep (scheduled, permuted-block parallel)", it(10), sched_updates, || {
        shard.clear_selected_residuals(&sel);
        shard.sweep_docs_parallel(&pool, 0, &ds, &phi, &tot, &sel, &params, true);
    });
    // scheduled docs under the power selection — the exact ABP t >= 2
    // configuration (doc schedule × word/topic schedule)
    let sched_sub_updates: f64 = scheduled
        .iter()
        .flat_map(|&d| corpus.row_range(d as usize))
        .map(|idx| {
            let wi = corpus.col[idx] as usize;
            if sel_p.word_sel[wi] {
                sel_p.topics_of(wi).map(|t| t.len()).unwrap_or(k) as f64
            } else {
                0.0
            }
        })
        .sum();
    bench(&mut recs, "bp sweep (scheduled subset, serial docs)", it(20), sched_sub_updates, || {
        shard.clear_selected_residuals(&sel_p);
        shard.sweep_docs(&scheduled, &phi, &tot, &sel_p, &params, true);
    });
    bench(&mut recs, "bp sweep (scheduled subset, permuted-block)", it(20), sched_sub_updates, || {
        shard.clear_selected_residuals(&sel_p);
        shard.sweep_docs_parallel(&pool, 0, &ds, &phi, &tot, &sel_p, &params, true);
    });

    // --- Gibbs samplers (tokens/s) ---
    let tokens = corpus.tokens();
    let mut gshard = GibbsShard::init(&corpus, k, &mut rng);
    let mut plain = PlainGs::new(k);
    let mut grng = Rng::new(2);
    bench(&mut recs, "gibbs sweep (plain GS)", it(5), tokens, || {
        gshard.sweep(&mut plain, &params, &mut grng);
    });
    let mut sparse = SparseGs::new(k);
    bench(&mut recs, "gibbs sweep (SparseLDA)", it(5), tokens, || {
        gshard.sweep(&mut sparse, &params, &mut grng);
    });
    let mut fast = FastGs::new(k);
    bench(&mut recs, "gibbs sweep (FastLDA)", it(5), tokens, || {
        gshard.sweep(&mut fast, &params, &mut grng);
    });

    // --- power selection (per coordinator iteration) ---
    let r = shard.r.clone();
    let sel_items = (corpus.w * k) as f64;
    bench(&mut recs, "power selection (partial sort W + topics)", it(50), sel_items, || {
        let _ = select_power(&r, corpus.w, k, &PowerParams::paper_default());
    });

    // --- allreduce: the full synchronization step. Serial reference
    //     (the pre-refactor leader loop) vs the retired leader-pool path
    //     (two chunked passes + serial scatter, fresh buffers per call)
    //     vs the owner-sliced reduce-scatter (one fused dispatch, reused
    //     scratch). All bitwise-equal on the replicated matrices; the
    //     owner split buys leader wall-clock and kills alloc churn. ---
    let nw = 8;
    let cluster = Cluster::new(nw, 0);
    let len = corpus.w * k;
    let mut arng = Rng::new(9);
    let srcs: Vec<Mutex<BenchSource>> = (0..nw)
        .map(|_| {
            Mutex::new(BenchSource {
                dphi: (0..len).map(|_| arng.f32() * 2.0 - 0.5).collect(),
                r: (0..len).map(|_| arng.f32()).collect(),
            })
        })
        .collect();
    let phi_acc: Vec<f32> = (0..len).map(|_| arng.f32()).collect();
    let dense_items = (len * nw) as f64;
    let mut ser_st = SerialState::new(&phi_acc, k);
    let mut st = GlobalState::new(&phi_acc, k);
    let mut scratch = SyncScratch::default();
    let dense_plan = ReducePlan::Dense { len };
    bench(&mut recs, "allreduce dense serial (reference step)", it(20), dense_items, || {
        serial_reference_step(&dense_plan, k, &phi_acc, &srcs, &mut ser_st);
    });
    bench(&mut recs, "allreduce dense leader-pool (chunked)", it(20), dense_items, || {
        allreduce_step_pool(&cluster, &dense_plan, &phi_acc, &srcs, &mut st);
    });
    bench(&mut recs, "allreduce dense owner-sliced (fused)", it(20), dense_items, || {
        allreduce_step(&cluster, &dense_plan, &phi_acc, &srcs, &mut st, &mut scratch);
    });

    // subset at the paper's power-selection density: the same plan-order
    // gather + reduce + scatter on every path
    let idx = select_power(&r, corpus.w, k, &PowerParams::paper_default()).flat_indices(k);
    let sub_plan = ReducePlan::Subset { indices: &idx };
    let sub_items = (idx.len() * nw) as f64;
    bench(&mut recs, "allreduce subset serial (reference step)", it(100), sub_items, || {
        serial_reference_step(&sub_plan, k, &phi_acc, &srcs, &mut ser_st);
    });
    bench(&mut recs, "allreduce subset leader-pool (chunked)", it(100), sub_items, || {
        allreduce_step_pool(&cluster, &sub_plan, &phi_acc, &srcs, &mut st);
    });
    // spawn-threshold sweep (Cluster::with_spawn_threshold): the same
    // subset step at three chunking grains. The rows live in their own
    // JSON object (not items_per_sec) so the trajectory keys stay stable.
    let mut thr_ips = [0.0f64; 3];
    for (i, thr) in [1024usize, 8192, 65536].into_iter().enumerate() {
        let cl = Cluster::new(nw, 0).with_spawn_threshold(thr);
        let row = bench(
            &mut recs,
            &format!("allreduce subset leader-pool (thr={thr})"),
            it(100),
            sub_items,
            || {
                allreduce_step_pool(&cl, &sub_plan, &phi_acc, &srcs, &mut st);
            },
        );
        thr_ips[i] = row.ips;
        let _ = recs.pop();
    }
    bench(&mut recs, "allreduce subset owner-sliced (fused)", it(100), sub_items, || {
        allreduce_step(&cluster, &sub_plan, &phi_acc, &srcs, &mut st, &mut scratch);
    });
    // the two pipelines: per-worker double-buffered rounds (retained
    // baseline) vs the slice-granular ready-counter pipeline the
    // coordinator's overlap mode now runs
    bench(&mut recs, "allreduce subset pipelined (per-worker)", it(100), sub_items, || {
        allreduce_step_overlap_rounds(
            &cluster, &sub_plan, &phi_acc, &srcs, &mut st, &mut scratch,
        );
    });
    bench(&mut recs, "allreduce subset pipelined (slice-granular)", it(100), sub_items, || {
        allreduce_step_overlap(&cluster, &sub_plan, &phi_acc, &srcs, &mut st, &mut scratch);
    });
    // sharded storage mode: the same owner-sliced fold landing in the
    // per-owner *stored* slices — no dense replica anywhere; each
    // worker's resident φ̂ is one row-aligned slice (O(W·K/N))
    let os = OwnerSlices::row_aligned(len, k, nw);
    let acc_parts: Vec<Vec<f32>> =
        (0..nw).map(|n| phi_acc[os.range(n)].to_vec()).collect();
    let mut sh_st = ShardedState::new(&acc_parts, k, os);
    let mut sh_scratch = SyncScratch::default();
    bench(&mut recs, "allreduce dense sharded (owner-store)", it(20), dense_items, || {
        allreduce_step_sharded(
            &cluster, &dense_plan, &acc_parts, &srcs, &mut sh_st, &mut sh_scratch,
        );
    });
    bench(&mut recs, "allreduce subset sharded (owner-store)", it(100), sub_items, || {
        allreduce_step_sharded(
            &cluster, &sub_plan, &acc_parts, &srcs, &mut sh_st, &mut sh_scratch,
        );
    });
    // the owner-store fold must land on the replicated oracle's bits —
    // replay one dense step on fresh state for both paths and compare
    {
        let mut oracle = GlobalState::new(&phi_acc, k);
        allreduce_step(&cluster, &dense_plan, &phi_acc, &srcs, &mut oracle, &mut scratch);
        let mut fresh = ShardedState::new(&acc_parts, k, os);
        allreduce_step_sharded(
            &cluster, &dense_plan, &acc_parts, &srcs, &mut fresh, &mut sh_scratch,
        );
        assert_eq!(
            fresh.render_dense(),
            oracle.phi_eff,
            "sharded allreduce diverged from the replicated oracle"
        );
        println!(
            "sharded resident phi+r per worker: {} KB (replicated: {} KB)",
            fresh.resident_bytes_per_worker() / 1024,
            2 * 4 * len / 1024
        );
    }

    // --- overlap efficiency: a short pipelined POBP fit on a
    //     compute-bound config; 1 − total/(compute+comm) is the fraction
    //     of the serialized cost the pipeline hides ---
    let ov_cfg = PobpConfig {
        n_workers: 4,
        nnz_budget: 8_000,
        max_iters: if smoke { 3 } else { 10 },
        overlap: true,
        net: NetModel::infiniband_for_scale(k, corpus.w),
        ..Default::default()
    };
    let ov = fit(&corpus, &params, &ov_cfg);
    let overlap_eff = ov.ledger.overlap_efficiency();
    println!(
        "\noverlap efficiency (pipelined POBP, compute-bound): {overlap_eff:.4}  \
         (compute {:.3}s, comm {:.3}s, total {:.3}s)",
        ov.ledger.compute_secs,
        ov.ledger.comm_secs,
        ov.ledger.total_secs()
    );

    // --- storage modes: one sharded fit (runs in --smoke too, so CI's
    //     quick pass exercises the sharded sync path end to end) pinned
    //     bitwise against the replicated oracle, plus the per-worker
    //     resident φ̂ bytes the mode is for ---
    let store_n = 4;
    let sh_cfg = PobpConfig {
        n_workers: store_n,
        nnz_budget: 8_000,
        max_iters: if smoke { 3 } else { 10 },
        storage: PhiStorageMode::Sharded,
        net: NetModel::infiniband_for_scale(k, corpus.w),
        ..Default::default()
    };
    let sh_fit = fit(&corpus, &params, &sh_cfg);
    let rep_fit = fit(
        &corpus,
        &params,
        &PobpConfig { storage: PhiStorageMode::Replicated, ..sh_cfg },
    );
    assert_eq!(
        sh_fit.model.phi_wk, rep_fit.model.phi_wk,
        "sharded fit diverged from the replicated oracle"
    );
    // φ̂ + r pairs: replicated keeps both W·K replicas per worker;
    // sharded keeps one row-aligned slice of each
    let rep_resident = 2 * 4 * corpus.w * k;
    let sh_resident =
        2 * PhiShard::sharded(corpus.w, k, store_n).resident_bytes_per_worker();
    println!(
        "\nstorage modes (N={store_n}): sharded fit bitwise == replicated; \
         resident phi+r per worker {} KB vs {} KB",
        sh_resident / 1024,
        rep_resident / 1024
    );
    // the big-K claim, analytically (PUBMED W, K = 8000): the dense
    // replica alone blows the paper's 2 GB per-processor budget, the
    // owner slice fits with room for the working set
    let bigk = MemModel {
        docs_resident: 1000,
        nnz_resident: 45_000,
        tokens_resident: 0,
        k: 8000,
        w: 141_043,
    };
    let bigk_n = 8;
    let budget = 2usize * (1 << 30);
    let bigk_replica = bigk.phi_replica_bytes();
    let bigk_sharded = bigk.phi_sharded_bytes(bigk_n, bigk.nnz_resident);
    assert!(bigk_replica > budget, "big-K config must exceed the budget replicated");
    assert!(bigk_sharded < budget, "big-K config must fit sharded");
    println!(
        "big-K analytic (W={}, K={}, N={bigk_n}): replica {} MB > {} MB budget; \
         sharded slice {} MB",
        bigk.w,
        bigk.k,
        bigk_replica / (1 << 20),
        budget / (1 << 20),
        bigk_sharded / (1 << 20)
    );

    // --- resilience (Contract 6): the kill-and-recover matrix — runs in
    //     --smoke too, so every CI pass kills a training run at each
    //     sync phase in both storage modes and asserts the recovered
    //     result lands on the uninterrupted oracle's bits ---
    let ck_root = std::env::temp_dir()
        .join(format!("pobp-microbench-ckpt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ck_root);
    let res_iters = 4;
    let res_base = PobpConfig {
        n_workers: 3,
        // global budget 12k nnz/batch: several mini-batches on this
        // corpus, so batch-1 kills recover from a real checkpoint
        nnz_budget: 4_000,
        max_iters: res_iters,
        converge_thresh: 0.0,
        net: NetModel::infiniband_for_scale(k, corpus.w),
        ..Default::default()
    };
    let mut recoveries = 0usize;
    let mut replay_secs = 0.0;
    let mut oracle_secs = 0.0;
    for mode in [PhiStorageMode::Replicated, PhiStorageMode::Sharded] {
        let mode_name = match mode {
            PhiStorageMode::Replicated => "replicated",
            PhiStorageMode::Sharded => "sharded",
        };
        let cfg = PobpConfig { storage: mode, ..res_base.clone() };
        let oracle = fit(&corpus, &params, &cfg);
        let batches = oracle.history.iter().map(|h| h.batch).max().unwrap_or(0) + 1;
        assert!(batches >= 3, "kill matrix needs >= 3 mini-batches, got {batches}");
        oracle_secs += oracle.ledger.total_secs();
        for (phase, iter) in [
            (SyncPhase::Sweep, 2),
            (SyncPhase::MidReduce, 3),
            (SyncPhase::Fold, res_iters + 1),
        ] {
            let dir = ck_root.join(format!("{mode_name}-{}", phase.name()));
            let plan = FaultPlan::kill(1, iter, phase, 0);
            let got = fit_resilient(
                &corpus,
                &params,
                &cfg,
                &ResilienceConfig::in_dir(&dir),
                Some(&plan),
            )
            .unwrap_or_else(|e| {
                panic!("kill-and-recover ({mode_name}, {}): {e}", phase.name())
            });
            assert_eq!(plan.kills_remaining(), 0, "kill point never reached");
            assert!(got.ledger.recovery_count >= 1, "run was never killed");
            assert_eq!(
                got.model.phi_wk, oracle.model.phi_wk,
                "recovered fit diverged from the oracle ({mode_name}, {})",
                phase.name()
            );
            assert_eq!(
                got.ledger.total_secs().to_bits(),
                oracle.ledger.total_secs().to_bits(),
                "recovered ledger diverged from the oracle ({mode_name}, {})",
                phase.name()
            );
            recoveries += got.ledger.recovery_count as usize;
            replay_secs += got.ledger.recovery_replay_secs;
        }
    }
    println!(
        "\nkill-and-recover matrix: {recoveries} kills absorbed (2 storage modes x \
         sweep/mid-reduce/fold), all bitwise == oracle; replay overhead {:.3}s \
         on {:.3}s of oracle time",
        replay_secs, oracle_secs
    );

    // checkpoint serialize/restore throughput (bytes/s) on a real
    // checkpoint the matrix left behind
    let ck_path = list_checkpoints(&ck_root.join("replicated-sweep"))
        .ok()
        .and_then(|mut v| v.pop())
        .expect("kill-and-recover left no checkpoint behind");
    let ck = Checkpoint::load(&ck_path).expect("checkpoint unreadable");
    let ck_bytes = std::fs::metadata(&ck_path).map(|m| m.len() as f64).unwrap_or(0.0);
    let ck_bench_dir = ck_root.join("bench");
    bench(&mut recs, "checkpoint write (encode+fsync+rename)", it(20), ck_bytes, || {
        ck.write(&ck_bench_dir, 2).expect("checkpoint write failed");
    });
    bench(&mut recs, "checkpoint restore (decode+verify)", it(20), ck_bytes, || {
        std::hint::black_box(Checkpoint::load(&ck_path).expect("checkpoint load failed"));
    });
    let _ = std::fs::remove_dir_all(&ck_root);

    // --- tcp loopback calibration (Contract 8): push the subset
    //     gather-sized payload through a real 127.0.0.1 round-trip and
    //     score the α–β estimate against the measured seconds with the
    //     same rule the distributed ledger applies to every recorded
    //     segment (NetModel::calibration_error_secs). Loopback is not
    //     gige, so a large error here is the *expected* honest answer —
    //     the row exists so the measured/modeled pair is in the JSON
    //     trajectory, not to flatter the model. ---
    let seg_bytes = idx.len() * 4;
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let echo_addr = listener.local_addr().expect("loopback addr");
    let echo = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().expect("echo accept");
        let mut buf = vec![0u8; 64 * 1024];
        loop {
            let n = s.read(&mut buf).expect("echo read");
            if n == 0 {
                break;
            }
            s.write_all(&buf[..n]).expect("echo write");
        }
    });
    let mut stream = TcpStream::connect(echo_addr).expect("connect loopback");
    stream.set_nodelay(true).ok();
    let seg = vec![0x5au8; seg_bytes];
    let mut back = vec![0u8; seg_bytes];
    let mut best_rtt = f64::INFINITY;
    for _ in 0..it(20).max(3) {
        let t0 = Instant::now();
        stream.write_all(&seg).expect("loopback write");
        stream.read_exact(&mut back).expect("loopback read");
        best_rtt = best_rtt.min(t0.elapsed().as_secs_f64());
    }
    drop(stream);
    echo.join().expect("echo thread");
    let wire_measured = best_rtt / 2.0; // one-way segment time
    let wire_model = NetModel::gige();
    let wire_cal_err = wire_model.calibration_error_secs(seg_bytes, 2, wire_measured);
    println!(
        "\ntcp loopback calibration: {seg_bytes} B segment, measured {:.3}ms one-way, \
         gige reduce-scatter model off by {:+.3}ms",
        wire_measured * 1e3,
        wire_cal_err * 1e3
    );

    // --- wire recovery (Contract 9): the coordinator through the
    //     in-process codec carrier, clean vs under a seeded chaos
    //     schedule (bit-flips, truncations, drops, resets, duplicates,
    //     delays on ~30% of frame transmissions). Runs in --smoke too,
    //     so every CI pass recovers injected wire faults and asserts
    //     the chaotic fit lands on the clean run's exact bits; the
    //     recorded ratio is the retry/reconnect overhead of the
    //     supervision layer. ---
    let wr_permille = 300u32;
    let wr_cfg = PobpConfig {
        n_workers: 2,
        nnz_budget: 8_000,
        max_iters: if smoke { 3 } else { 6 },
        converge_thresh: 0.0,
        net: NetModel::infiniband_for_scale(k, corpus.w),
        ..Default::default()
    };
    let clean = {
        let mut tp = InProcessTransport::new(wr_cfg.n_workers, wr_cfg.max_threads);
        fit_dist(&corpus, &params, &wr_cfg, &mut tp).expect("clean dist fit")
    };
    let chaotic = {
        let mut tp = InProcessTransport::new(wr_cfg.n_workers, wr_cfg.max_threads)
            .with_chaos(ChaosPlan::seeded(4242, wr_permille));
        fit_dist(&corpus, &params, &wr_cfg, &mut tp).expect("chaotic dist fit")
    };
    assert_eq!(
        chaotic.model.phi_wk, clean.model.phi_wk,
        "chaotic fit diverged from the clean run (Contract 9)"
    );
    assert_eq!(
        chaotic.ledger.wire_bytes, clean.ledger.wire_bytes,
        "retransmissions leaked into the modeled wire bytes"
    );
    assert!(chaotic.ledger.chaos_faults > 0, "seeded chaos drew no faults");
    // the supervised wire's useful throughput: modeled payload traffic
    // over wall time, so the chaos row pays for every retransmission
    // without getting credit for it
    let wr_bytes = clean.ledger.wire_bytes as f64;
    let row_clean = bench(&mut recs, "dist fit (inprocess codec, clean)", it(3), wr_bytes, || {
        let mut tp = InProcessTransport::new(wr_cfg.n_workers, wr_cfg.max_threads);
        std::hint::black_box(
            fit_dist(&corpus, &params, &wr_cfg, &mut tp).expect("clean dist fit"),
        );
    });
    let row_chaos =
        bench(&mut recs, "dist fit (inprocess codec, seeded chaos)", it(3), wr_bytes, || {
            let mut tp = InProcessTransport::new(wr_cfg.n_workers, wr_cfg.max_threads)
                .with_chaos(ChaosPlan::seeded(4242, wr_permille));
            std::hint::black_box(
                fit_dist(&corpus, &params, &wr_cfg, &mut tp).expect("chaotic dist fit"),
            );
        });
    let retry_overhead =
        if row_chaos.ips > 0.0 { row_clean.ips / row_chaos.ips } else { 0.0 };
    println!(
        "\nwire recovery (permille {wr_permille}): {} faults injected, {} frames \
         retransmitted ({} B), {} reconnects; chaotic fit bitwise == clean; \
         retry overhead {retry_overhead:.2}x",
        chaotic.ledger.chaos_faults,
        chaotic.ledger.retrans_frames,
        chaotic.ledger.retrans_bytes,
        chaotic.ledger.reconnects
    );

    // --- machine-readable record for the cross-PR perf trajectory ---
    let find = |recs: &[(String, f64)], name: &str| {
        recs.iter().find(|(n, _)| n == name).map(|&(_, v)| v).unwrap_or(0.0)
    };
    let serial = find(&recs, "bp sweep (full, serial reference)");
    let par = find(&recs, "bp sweep (full, doc-parallel)");
    let speedup = if serial > 0.0 { par / serial } else { 0.0 };
    let sched_ser = find(&recs, "bp sweep (scheduled, serial sweep_docs)");
    let sched_par = find(&recs, "bp sweep (scheduled, permuted-block parallel)");
    let sched_speedup = if sched_ser > 0.0 { sched_par / sched_ser } else { 0.0 };
    // per-iteration ABP leader overhead: clone+rebuild vs incremental
    // snapshot on the power-subset workload (acceptance: >= 5x)
    let pub_clone = find(&recs, "phi publish (clone+rebuild, power subset)");
    let pub_incr = find(&recs, "phi publish (incremental, power subset)");
    let abp_iter_overhead_speedup =
        if pub_clone > 0.0 { pub_incr / pub_clone } else { 0.0 };
    // Contract 7 kernel + pinning ratios (same keys as the C mirror in
    // tools/sweep_mirror.c, so the cross-PR tooling reads one shape)
    let simd_full =
        if row_fus.ips > 0.0 { row_wid.ips / row_fus.ips } else { 0.0 };
    let simd_sub = if row_sub_sc.ips > 0.0 {
        row_sub_wid.ips / row_sub_sc.ips
    } else {
        0.0
    };
    let parp = find(&recs, "bp sweep (full, doc-parallel pinned)");
    let pin_speedup = if par > 0.0 { parp / par } else { 0.0 };
    let isa = if !simd::wide_compiled() {
        "none"
    } else if cfg!(target_arch = "x86_64") {
        "sse2"
    } else {
        "neon"
    };
    let results = Json::Obj(
        recs.into_iter().map(|(n, v)| (n, Json::Num(v))).collect(),
    );
    // same outer schema as tools/sweep_mirror.c (the no-rustc fallback
    // generator), so cross-PR tooling reads one shape
    let report = Json::obj(vec![
        ("bench", Json::from("microbench")),
        ("generator", Json::from("benches/microbench.rs")),
        ("host", Json::obj(vec![("threads", Json::from(pool.pool_threads()))])),
        ("corpus", Json::obj(vec![
            ("docs", Json::from(corpus.docs())),
            ("w", Json::from(corpus.w)),
            ("nnz", Json::from(corpus.nnz())),
            ("k", Json::from(k)),
        ])),
        ("full_sweep_speedup_vs_serial", Json::from(speedup)),
        ("scheduled_sweep_speedup_vs_serial", Json::from(sched_speedup)),
        ("abp_iter_overhead_speedup", Json::from(abp_iter_overhead_speedup)),
        ("overlap_efficiency", Json::from(overlap_eff)),
        ("kernel_simd", Json::obj(vec![
            ("compiled", Json::from(simd::wide_compiled())),
            ("isa", Json::from(isa)),
            ("full_sweep_speedup_vs_scalar", Json::from(simd_full)),
            ("subset_sweep_speedup_vs_scalar", Json::from(simd_sub)),
            (
                "validated",
                Json::from(
                    "bitwise vs scalar (tests/kernel_equiv.rs: full + packed \
                     subset sweeps, all state + residuals)",
                ),
            ),
        ])),
        ("pinning", Json::obj(vec![(
            "full_sweep_pinned_speedup_vs_floating",
            Json::from(pin_speedup),
        )])),
        ("spawn_threshold_items_per_sec", Json::obj(vec![
            ("1024", Json::from(thr_ips[0])),
            ("8192", Json::from(thr_ips[1])),
            ("65536", Json::from(thr_ips[2])),
        ])),
        ("timing_variance_median_over_min", Json::obj(vec![
            ("bp sweep (full, fused serial)", Json::from(row_fus.variance())),
            ("bp sweep (full, simd serial)", Json::from(row_wid.variance())),
            ("bp sweep (power subset, doc-order)", Json::from(row_sub.variance())),
            ("bp sweep (power subset, simd)", Json::from(row_sub_wid.variance())),
        ])),
        ("resilience", Json::obj(vec![
            ("kill_recover_cases", Json::from(6usize)),
            ("recoveries", Json::from(recoveries)),
            ("checkpoint_bytes", Json::from(ck_bytes as usize)),
            ("recovery_replay_secs", Json::from(replay_secs)),
            (
                "recovery_overhead_frac",
                Json::from(if oracle_secs > 0.0 { replay_secs / oracle_secs } else { 0.0 }),
            ),
        ])),
        ("tcp_loopback_calibration", Json::obj(vec![
            ("segment_bytes", Json::from(seg_bytes)),
            ("measured_one_way_secs", Json::from(wire_measured)),
            ("modeled_gige_reduce_scatter_secs", Json::from(wire_measured - wire_cal_err)),
            ("calibration_error_secs", Json::from(wire_cal_err)),
        ])),
        ("wire_recovery", Json::obj(vec![
            ("chaos_permille", Json::from(wr_permille as usize)),
            ("chaos_faults", Json::from(chaotic.ledger.chaos_faults as usize)),
            ("retrans_frames", Json::from(chaotic.ledger.retrans_frames as usize)),
            ("retrans_bytes", Json::from(chaotic.ledger.retrans_bytes as usize)),
            ("reconnects", Json::from(chaotic.ledger.reconnects as usize)),
            ("backoff_wait_secs", Json::from(chaotic.ledger.backoff_wait_secs)),
            ("retry_overhead_time_ratio", Json::from(retry_overhead)),
            (
                "validated",
                Json::from(
                    "chaotic fit bitwise == clean dist fit (Contract 9; the full \
                     fault matrix incl. real sockets is tests/chaos_equiv.rs)",
                ),
            ),
        ])),
        ("phi_mem_modes", Json::obj(vec![
            ("n_workers", Json::from(store_n)),
            ("replicated_resident_bytes_per_worker", Json::from(rep_resident)),
            ("sharded_resident_bytes_per_worker", Json::from(sh_resident)),
            ("bigk_w", Json::from(bigk.w)),
            ("bigk_k", Json::from(bigk.k)),
            ("bigk_n", Json::from(bigk_n)),
            ("bigk_budget_bytes", Json::from(budget)),
            ("bigk_replicated_bytes_per_worker", Json::from(bigk_replica)),
            ("bigk_sharded_bytes_per_worker", Json::from(bigk_sharded)),
        ])),
        ("items_per_sec", results),
    ]);
    println!("\nfull-sweep speedup vs serial reference: {speedup:.2}x");
    println!(
        "simd kernel speedup vs scalar ({isa}): full {simd_full:.2}x, \
         subset {simd_sub:.2}x; pinned-vs-floating {pin_speedup:.2}x"
    );
    println!("scheduled-sweep speedup vs serial sweep_docs: {sched_speedup:.2}x");
    println!(
        "abp iter-overhead speedup (snapshot vs clone+rebuild): \
         {abp_iter_overhead_speedup:.2}x"
    );
    if smoke {
        println!("--smoke: skipping BENCH_microbench.json write");
    } else {
        std::fs::write("BENCH_microbench.json", format!("{report}\n")).ok();
        println!("wrote BENCH_microbench.json");
    }
}

/// Worker double for the allreduce rows: dense partials only (the trait
/// default supplies the plan-order export).
struct BenchSource {
    dphi: Vec<f32>,
    r: Vec<f32>,
}

impl ReduceSource for BenchSource {
    fn dense_parts(&self) -> (&[f32], &[f32]) {
        (&self.dphi, &self.r)
    }
}
