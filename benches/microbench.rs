//! Microbenchmarks of the L3 hot paths (criterion substitute): the sparse
//! BP sweep (serial reference vs fused vs doc-parallel), the Gibbs
//! samplers, the power selection partial sort, and the allreduce. These
//! are the §Perf numbers in EXPERIMENTS.md; alongside the human table the
//! run emits `BENCH_microbench.json` (name → items/s) so the perf
//! trajectory is machine-trackable across PRs.

#[path = "common/mod.rs"]
mod common;

use std::time::Instant;

use pobp::comm::{reduce_chunked, reduce_sum_into, Cluster};
use pobp::engine::bp::{Selection, ShardBp};
use pobp::engine::fgs::FastGs;
use pobp::engine::gibbs::{GibbsShard, PlainGs};
use pobp::engine::sgs::SparseGs;
use pobp::metrics::sig;
use pobp::sched::{select_power, PowerParams};
use pobp::util::json::Json;
use pobp::util::rng::Rng;

fn bench<F: FnMut()>(
    recs: &mut Vec<(String, f64)>,
    name: &str,
    iters: usize,
    work_items: f64,
    mut f: F,
) {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let ips = work_items / per;
    println!(
        "{name:42} {:>12}/iter   {:>14} items/s",
        format!("{:.3}ms", per * 1e3),
        sig(ips)
    );
    recs.push((name.to_string(), ips));
}

fn main() {
    common::banner("microbench", "hot-path throughput", "enron-sim, K=50");
    let k = 50;
    let corpus = common::corpus("enron", k, 1);
    let params = common::params(k);
    println!(
        "corpus: D={} W={} NNZ={} tokens={}\n",
        corpus.docs(), corpus.w, corpus.nnz(), corpus.tokens()
    );
    let mut recs: Vec<(String, f64)> = Vec::new();

    // --- BP sweep (the POBP worker inner loop): the pre-fusion serial
    //     kernel (kept as the equivalence oracle), the fused serial
    //     kernel, and the doc-parallel engine on the full OS-thread
    //     pool (the N = 1 coordinator configuration) ---
    let pool = Cluster::new(1, 0);
    let mut rng = Rng::new(1);
    let mut shard = ShardBp::init(corpus.clone(), k, &mut rng);
    let sel = Selection::full(corpus.w);
    let updates = corpus.nnz() as f64 * k as f64;
    // frozen phi snapshot: timing measures the sweep itself, not the
    // leader's phi rebuild (that cost is the allreduce bench below)
    let phi = shard.dphi.clone();
    let mut tot = vec![0f32; k];
    for row in phi.chunks_exact(k) {
        for (t, &v) in row.iter().enumerate() {
            tot[t] += v;
        }
    }
    bench(&mut recs, "bp sweep (full, serial reference)", 10, updates, || {
        shard.clear_selected_residuals(&sel);
        shard.sweep_reference(&phi, &tot, &sel, &params, true);
    });
    bench(&mut recs, "bp sweep (full, fused serial)", 10, updates, || {
        shard.clear_selected_residuals(&sel);
        shard.sweep(&phi, &tot, &sel, &params, true);
    });
    bench(&mut recs, "bp sweep (full, doc-parallel)", 10, updates, || {
        shard.sweep_parallel(&pool, 0, &phi, &tot, &sel, &params, true);
    });

    // power-subset sweep (same schedule the coordinator runs at t >= 2);
    // work items = Σ_selected-words entries(w) × topics(w) — the true
    // per-pair update count, from the shard's inverted index instead of
    // the old O(W·D·log nnz) binary-search scan (which also multiplied
    // every word by the *first* selected word's topic count)
    let ps = select_power(&shard.r, corpus.w, k, &PowerParams::paper_default());
    let sel_p = Selection::from_power(&ps, corpus.w);
    let active_entries: usize = (0..corpus.w)
        .filter(|&wi| sel_p.word_sel[wi])
        .map(|wi| shard.word_entries(wi))
        .sum();
    let sub_updates: f64 = (0..corpus.w)
        .filter(|&wi| sel_p.word_sel[wi])
        .map(|wi| {
            let topics = sel_p.topics_of(wi).map(|t| t.len()).unwrap_or(k);
            (shard.word_entries(wi) * topics) as f64
        })
        .sum();
    println!(
        "power subset: {} active entries, {} pair updates",
        active_entries, sub_updates
    );
    bench(&mut recs, "bp sweep (power subset, doc-order)", 10, sub_updates, || {
        shard.clear_selected_residuals(&sel_p);
        shard.sweep(&phi, &tot, &sel_p, &params, true);
    });
    bench(&mut recs, "bp sweep (power subset, inverted idx)", 10, sub_updates, || {
        shard.clear_selected_residuals(&sel_p);
        shard.sweep_selected(&phi, &tot, &sel_p, &params, true);
    });
    bench(&mut recs, "bp sweep (power subset, doc-parallel)", 10, sub_updates, || {
        shard.sweep_parallel(&pool, 0, &phi, &tot, &sel_p, &params, true);
    });

    // --- Gibbs samplers (tokens/s) ---
    let tokens = corpus.tokens();
    let mut gshard = GibbsShard::init(&corpus, k, &mut rng);
    let mut plain = PlainGs::new(k);
    let mut grng = Rng::new(2);
    bench(&mut recs, "gibbs sweep (plain GS)", 5, tokens, || {
        gshard.sweep(&mut plain, &params, &mut grng);
    });
    let mut sparse = SparseGs::new(k);
    bench(&mut recs, "gibbs sweep (SparseLDA)", 5, tokens, || {
        gshard.sweep(&mut sparse, &params, &mut grng);
    });
    let mut fast = FastGs::new(k);
    bench(&mut recs, "gibbs sweep (FastLDA)", 5, tokens, || {
        gshard.sweep(&mut fast, &params, &mut grng);
    });

    // --- power selection (per coordinator iteration) ---
    let r = shard.r.clone();
    bench(&mut recs, "power selection (partial sort W + topics)", 50, (corpus.w * k) as f64, || {
        let _ = select_power(&r, corpus.w, k, &PowerParams::paper_default());
    });

    // --- leader-side allreduce, before/after: the pre-refactor serial
    //     leader loop vs the chunked parallel reduction on the cluster
    //     thread pool (comm::allreduce). Same bitwise result; the
    //     parallel path buys leader wall-clock on multi-core hosts. ---
    let nw = 8;
    let cluster = Cluster::new(nw, 0);
    let partials: Vec<Vec<f32>> = (0..nw).map(|i| vec![i as f32; corpus.w * k]).collect();
    let parts: Vec<&[f32]> = partials.iter().map(|p| p.as_slice()).collect();
    let mut g = vec![0f32; corpus.w * k];
    let dense_items = (corpus.w * k * nw) as f64;
    bench(&mut recs, "allreduce dense serial (old leader loop)", 20, dense_items, || {
        g.fill(0.0);
        reduce_sum_into(&mut g, &partials);
        std::hint::black_box(&g);
    });
    bench(&mut recs, "allreduce dense parallel (chunked)", 20, dense_items, || {
        reduce_chunked(&cluster, None, &parts, &mut g);
        std::hint::black_box(&g);
    });

    // subset variant at the paper's power-selection density: both sides
    // reduce the same packed plan-order buffers, so the comparison
    // isolates the chunked reduction itself
    let idx = select_power(&r, corpus.w, k, &PowerParams::paper_default()).flat_indices(k);
    let sub_partials: Vec<Vec<f32>> = (0..nw).map(|i| vec![i as f32; idx.len()]).collect();
    let sub_parts: Vec<&[f32]> = sub_partials.iter().map(|p| p.as_slice()).collect();
    let mut red = vec![0f32; idx.len()];
    let sub_items = (idx.len() * nw) as f64;
    bench(&mut recs, "allreduce subset serial (packed)", 200, sub_items, || {
        red.fill(0.0);
        reduce_sum_into(&mut red, &sub_partials);
        std::hint::black_box(&red);
    });
    bench(&mut recs, "allreduce subset parallel (chunked)", 200, sub_items, || {
        reduce_chunked(&cluster, None, &sub_parts, &mut red);
        std::hint::black_box(&red);
    });

    // --- machine-readable record for the cross-PR perf trajectory ---
    let find = |recs: &[(String, f64)], name: &str| {
        recs.iter().find(|(n, _)| n == name).map(|&(_, v)| v).unwrap_or(0.0)
    };
    let serial = find(&recs, "bp sweep (full, serial reference)");
    let par = find(&recs, "bp sweep (full, doc-parallel)");
    let speedup = if serial > 0.0 { par / serial } else { 0.0 };
    let results = Json::Obj(
        recs.into_iter().map(|(n, v)| (n, Json::Num(v))).collect(),
    );
    // same outer schema as tools/sweep_mirror.c (the no-rustc fallback
    // generator), so cross-PR tooling reads one shape
    let report = Json::obj(vec![
        ("bench", Json::from("microbench")),
        ("generator", Json::from("benches/microbench.rs")),
        ("host", Json::obj(vec![("threads", Json::from(pool.pool_threads()))])),
        ("corpus", Json::obj(vec![
            ("docs", Json::from(corpus.docs())),
            ("w", Json::from(corpus.w)),
            ("nnz", Json::from(corpus.nnz())),
            ("k", Json::from(k)),
        ])),
        ("full_sweep_speedup_vs_serial", Json::from(speedup)),
        ("items_per_sec", results),
    ]);
    std::fs::write("BENCH_microbench.json", format!("{report}\n")).ok();
    println!("\nfull-sweep speedup vs serial reference: {speedup:.2}x");
    println!("wrote BENCH_microbench.json");
}
