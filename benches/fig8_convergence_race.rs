//! Fig. 8 — predictive perplexity as a function of (simulated) training
//! time for POBP / PFGS / PSGS / YLDA / PVB on the three big corpora with
//! 256 processors.
//!
//! Paper setting: NYTIMES/PUBMED/WIKIPEDIA, K = 2000, N = 256.
//! Here: the Table-3-scaled corpora, K = 100, N = 256 simulated workers.
//! Expected shape: POBP reaches the lowest perplexity fastest (10–100×
//! before the GS family, more before PVB); PVB is slowest and worst.

#[path = "common/mod.rs"]
mod common;

use pobp::corpus::split_tokens;
use pobp::metrics::{results_dir, sig, Table};
use pobp::repro::{perplexity_curve, run_algo, Algo, RunOpts};

fn main() {
    common::banner("Fig 8", "perplexity vs training time race", "big-3 sims, K=100, N=256 (simulated)");
    let k = 100;
    let mut t = Table::new("fig8_convergence_race", &["dataset", "algo", "sim_secs", "perplexity"]);

    for name in common::BIG3 {
        let corpus = common::corpus(name, k, 8);
        let params = common::params(k);
        let split = split_tokens(&corpus, 0.2, 8);
        println!(
            "{name}: D={} W={} tokens={}",
            corpus.docs(), corpus.w, corpus.tokens()
        );
        for algo in Algo::paper_set() {
            let o = RunOpts {
                n_workers: 256,
                iters: if common::full() { 120 } else { 40 },
                max_batch_iters: 30,
                snapshot_every: match algo {
                    Algo::Pobp => 4,
                    _ => 4,
                },
                ..common::opts(256, k)
            };
            let r = run_algo(algo, &split.train, &params, &o);
            let curve = perplexity_curve(&r, &split, &params, 8);
            for (secs, perp) in &curve {
                t.row(&[name.to_string(), algo.name().to_string(), sig(*secs), sig(*perp)]);
            }
            let last = curve.last().map(|&(_, p)| p).unwrap_or(f64::NAN);
            println!(
                "  {:10} final perplexity {:8}  sim time {:10}  (wall {:.1}s)",
                algo.name(), sig(last), sig(r.sim_secs()), r.wall_secs
            );
        }
    }
    t.save(&results_dir()).unwrap();
    println!("saved fig8_convergence_race.csv");
}
