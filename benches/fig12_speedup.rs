//! Fig. 12 — speedup vs number of processors on PUBMED.
//!
//! Paper setting: N ∈ {128, 256, 512, 1024}, K = 2000; baseline is the
//! single-processor PSGS time estimated from the smallest-N run assuming
//! perfect scaling (the paper uses "1/128 of the PSGS time on 128
//! processors" the same way). Here: N ∈ {16, 32, 64, 128, 256} simulated,
//! K = 100 on pubmed-sim.
//!
//! Expected shape: POBP's curve bends earliest (its optimal N* of Eq. 18
//! is smallest because its compute shrinks with the power subsets) but
//! sits highest; the GS family keeps climbing to larger N before
//! flattening; PVB is lowest.

#[path = "common/mod.rs"]
mod common;

use pobp::metrics::{results_dir, sig, Table};
use pobp::repro::{run_algo, Algo, RunOpts};

fn main() {
    common::banner("Fig 12", "speedup vs N processors", "pubmed-sim, K=100, N in {16..256}");
    let k = 100;
    let corpus = common::corpus("pubmed", k, 12);
    let params = common::params(k);
    let ns = [16usize, 32, 64, 128, 256];

    // baseline: PSGS on the smallest N, extrapolated to one processor
    let base_opts = RunOpts { n_workers: ns[0], ..common::opts(ns[0], k) };
    let base = run_algo(Algo::Psgs, &corpus, &params, &base_opts);
    let t1_est = base.sim_secs() * ns[0] as f64;
    println!(
        "baseline: PSGS on N={} -> sim {}s, single-processor estimate {}s\n",
        ns[0], sig(base.sim_secs()), sig(t1_est)
    );

    let mut t = Table::new("fig12_speedup", &["algo", "n", "sim_secs", "speedup"]);
    for algo in Algo::paper_set() {
        let mut prev_speedup = 0.0;
        for &n in &ns {
            let o = RunOpts { n_workers: n, ..common::opts(n, k) };
            let r = run_algo(algo, &corpus, &params, &o);
            let speedup = t1_est / r.sim_secs().max(1e-12);
            t.row(&[algo.name().to_string(), n.to_string(), sig(r.sim_secs()), sig(speedup)]);
            print!("{}@{n}: {:.1}  ", algo.name(), speedup);
            prev_speedup = speedup;
        }
        let _ = prev_speedup;
        println!();
    }
    println!();
    println!("{}", t.render());
    t.save(&results_dir()).unwrap();
    println!("saved fig12_speedup.csv");
}
