//! Fig. 11 — total (simulated) training time of every algorithm as a
//! function of K on the big corpora, 256 processors.
//!
//! Paper: POBP 5–100× faster than the others; PFGS/PSGS/YLDA comparable;
//! PVB slowest. Simulated time = measured shard compute (barrier max) +
//! modeled allreduce time.
//!
//! On top of the paper set, every (dataset, K) point runs the **overlap
//! ablation**: the same POBP configuration through the pipelined
//! synchronization stack (`RunOpts::overlap`, row `pobp+overlap`), whose
//! results are bitwise identical to `pobp` while the ledger charges
//! `max(compute, comm)` per iteration — the like-for-like comparison
//! against YLDA, which always overlaps its parameter-server traffic.

#[path = "common/mod.rs"]
mod common;

use pobp::metrics::{results_dir, sig, Table};
use pobp::repro::{run_algo, Algo, RunOpts};

fn main() {
    common::banner("Fig 11", "training time vs K", "big-3 sims, K sweep, N=256");
    let mut t = Table::new(
        "fig11_training_time",
        &["dataset", "k", "algo", "sim_secs", "compute_secs", "comm_secs", "speedup_vs_pobp"],
    );
    for name in common::BIG3 {
        for &k in &common::K_SWEEP {
            let corpus = common::corpus(name, k, 11);
            let params = common::params(k);
            let o = common::opts(256, k);
            let mut rows: Vec<(String, f64, f64, f64)> = Vec::new();
            for algo in Algo::paper_set() {
                let r = run_algo(algo, &corpus, &params, &o);
                // exposed comm (comm − overlap-hidden): the columns then
                // satisfy sim ≈ compute + comm for every algorithm,
                // overlapped (YLDA) included
                rows.push((
                    algo.name().to_string(),
                    r.sim_secs(),
                    r.ledger.compute_secs,
                    r.ledger.exposed_comm_secs(),
                ));
            }
            // overlap ablation: identical POBP arithmetic through the
            // pipelined stack — comm hidden behind compute where it fits
            let ov = run_algo(
                Algo::Pobp,
                &corpus,
                &params,
                &RunOpts { overlap: true, ..o.clone() },
            );
            rows.push((
                "pobp+overlap".to_string(),
                ov.sim_secs(),
                ov.ledger.compute_secs,
                ov.ledger.exposed_comm_secs(),
            ));
            let pobp = rows.iter().find(|(a, ..)| a == "pobp").unwrap().1;
            for (algo, sim, comp, comm) in &rows {
                t.row(&[
                    name.to_string(),
                    k.to_string(),
                    algo.clone(),
                    sig(*sim),
                    sig(*comp),
                    sig(*comm),
                    format!("{:.1}x", sim / pobp.max(1e-12)),
                ]);
            }
            println!(
                "{name} K={k}: pobp {}s, others {}",
                sig(pobp),
                rows.iter()
                    .filter(|(a, ..)| a != "pobp")
                    .map(|(a, s, ..)| format!("{a}={}s", sig(*s)))
                    .collect::<Vec<_>>()
                    .join(" ")
            );
        }
    }
    println!();
    println!("{}", t.render());
    t.save(&results_dir()).unwrap();
    println!("saved fig11_training_time.csv");
}
