//! Fig. 10 — communication time of every algorithm on the big corpora for
//! the K sweep, 256 processors.
//!
//! Paper: POBP consumes ~5–20% of the others' communication time; PVB is
//! the worst (floats, ~2× the GS family). Communication time here comes
//! from the byte-exact ledger + the 20 GB/s Infiniband α–β model
//! (DESIGN.md §Substitutions) — the bytes are exact, the seconds follow
//! the paper's published link parameters.
//!
//! Scale note: the paper's 5–20% needs λ_W·λ_K ≈ 0.0025 (K = 2000) and
//! T′ = 500 batch iterations. At bench scale K ≤ 100 forces λ_K ≥ 0.3
//! for accuracy (see fig7), and the batch algorithms converge in ~60
//! iterations — both shifts inflate POBP's *relative* comm time. The
//! `paper_protocol_ratio` column projects the measured bytes onto the
//! paper's T′ = 500 protocol so the regimes are comparable.

#[path = "common/mod.rs"]
mod common;

use pobp::metrics::{results_dir, sig, Table};
use pobp::repro::{run_algo, Algo};

fn main() {
    common::banner("Fig 10", "communication time per algorithm", "big-3 sims, K sweep, N=256");
    let mut t = Table::new(
        "fig10_comm_time",
        &["dataset", "k", "algo", "comm_secs", "payload_mb", "syncs",
          "pobp_ratio_pct", "paper_protocol_ratio_pct"],
    );
    for name in common::BIG3 {
        for &k in &common::K_SWEEP {
            let corpus = common::corpus(name, k, 10);
            let params = common::params(k);
            let o = common::opts(256, k);
            let mut comm: Vec<(Algo, f64, u64, usize)> = Vec::new();
            for algo in Algo::paper_set() {
                let r = run_algo(algo, &corpus, &params, &o);
                comm.push((
                    algo,
                    // exposed comm: overlapped algorithms (YLDA) pay only
                    // the fraction their computation cannot hide. The old
                    // ledger hack hard-zeroed YLDA's comm; this plots the
                    // honest residue, positive on comm-bound configs.
                    r.ledger.exposed_comm_secs(),
                    r.ledger.payload_bytes_total() / 1_000_000,
                    r.ledger.sync_count(),
                ));
            }
            let pobp_secs = comm
                .iter()
                .find(|(a, ..)| *a == Algo::Pobp)
                .map(|&(_, s, ..)| s)
                .unwrap();
            for (algo, secs, mb, syncs) in &comm {
                let ratio = pobp_secs / secs.max(1e-12) * 100.0;
                // batch algorithms at the paper's T' = 500 instead of the
                // bench's converged iteration count
                let paper_ratio = if *algo == Algo::Pobp {
                    100.0
                } else {
                    ratio * *syncs as f64 / 500.0
                };
                t.row(&[
                    name.to_string(),
                    k.to_string(),
                    algo.name().to_string(),
                    sig(*secs),
                    mb.to_string(),
                    syncs.to_string(),
                    format!("{ratio:.1}"),
                    format!("{paper_ratio:.1}"),
                ]);
            }
            let worst = comm.iter().map(|&(_, s, ..)| s).fold(0.0, f64::max);
            println!(
                "{name} K={k}: POBP comm {}s = {:.1}% of worst ({}s)",
                sig(pobp_secs), pobp_secs / worst * 100.0, sig(worst)
            );
        }
    }
    println!();
    println!("{}", t.render());
    t.save(&results_dir()).unwrap();
    println!("saved fig10_comm_time.csv");
}
