//! Fig. 6 — power-law structure of the residuals at iteration 10 on
//! ENRON: rank plots of the word residuals r_w and the per-word topic
//! residuals r_w(k), linear and log-log. The paper reports the top 10% of
//! words carrying ~79% of the total residual and the top 20% carrying
//! ~90%; this bench prints the same shares.
//!
//! Paper setting: ENRON, K = 500, iteration 10. Here: enron-sim, K = 50.

#[path = "common/mod.rs"]
mod common;

use pobp::engine::bp::{Selection, ShardBp};
use pobp::metrics::{results_dir, sig, Table};
use pobp::util::rng::Rng;

fn main() {
    common::banner("Fig 6", "residual rank distributions (power law)", "enron-sim, K=50, iter 10");
    let k = 50;
    let corpus = common::corpus("enron", k, 6);
    let w = corpus.w;
    let params = common::params(k);

    // batch BP for 10 iterations, single shard (residuals are the same
    // object the POBP coordinator synchronizes)
    let mut rng = Rng::new(6);
    let mut shard = ShardBp::init(corpus, k, &mut rng);
    let sel = Selection::full(w);
    for _ in 0..10 {
        let phi = shard.dphi.clone();
        let mut tot = vec![0f32; k];
        for row in phi.chunks_exact(k) {
            for (t, &v) in row.iter().enumerate() {
                tot[t] += v;
            }
        }
        shard.clear_selected_residuals(&sel);
        shard.sweep(&phi, &tot, &sel, &params, true);
    }

    // word residuals r_w (Eq. 10)
    let mut r_w: Vec<f64> = (0..w)
        .map(|wi| shard.r[wi * k..(wi + 1) * k].iter().map(|&v| v as f64).sum())
        .collect();
    r_w.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let total: f64 = r_w.iter().sum();
    let share = |frac: f64| -> f64 {
        let n = ((w as f64 * frac) as usize).max(1);
        r_w.iter().take(n).sum::<f64>() / total * 100.0
    };

    let mut tw = Table::new("fig6_word_residual_rank", &["rank", "residual", "log10_rank", "log10_residual"]);
    for (i, &v) in r_w.iter().enumerate().filter(|(_, &v)| v > 0.0) {
        tw.row(&[
            (i + 1).to_string(),
            sig(v),
            sig(((i + 1) as f64).log10()),
            sig(v.log10()),
        ]);
    }
    tw.save(&results_dir()).unwrap();

    // topic residuals r_w(k) of the hottest word (Fig. 6C/D)
    let hot = 0usize; // rank-1 word after sorting indices
    let mut hot_wi = 0usize;
    let mut hot_val = 0f64;
    for wi in 0..w {
        let s: f64 = shard.r[wi * k..(wi + 1) * k].iter().map(|&v| v as f64).sum();
        if s > hot_val {
            hot_val = s;
            hot_wi = wi;
        }
    }
    let _ = hot;
    let mut r_k: Vec<f64> = shard.r[hot_wi * k..(hot_wi + 1) * k]
        .iter()
        .map(|&v| v as f64)
        .collect();
    r_k.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut tk = Table::new("fig6_topic_residual_rank", &["rank", "residual", "log10_rank", "log10_residual"]);
    for (i, &v) in r_k.iter().enumerate().filter(|(_, &v)| v > 0.0) {
        tk.row(&[
            (i + 1).to_string(),
            sig(v),
            sig(((i + 1) as f64).log10()),
            sig(v.log10()),
        ]);
    }
    tk.save(&results_dir()).unwrap();

    println!("top 10% words carry {:.1}% of residual (paper: ~79%)", share(0.10));
    println!("top 20% words carry {:.1}% of residual (paper: ~90%)", share(0.20));
    // log-log straightness: fit slope over the head of the curve
    let pts: Vec<(f64, f64)> = r_w
        .iter()
        .enumerate()
        .take(w / 2)
        .filter(|(_, &v)| v > 0.0)
        .map(|(i, &v)| (((i + 1) as f64).ln(), v.ln()))
        .collect();
    let n = pts.len() as f64;
    let (sx, sy): (f64, f64) = pts.iter().fold((0.0, 0.0), |(a, b), (x, y)| (a + x, b + y));
    let (sxx, sxy): (f64, f64) = pts
        .iter()
        .fold((0.0, 0.0), |(a, b), (x, y)| (a + x * x, b + x * y));
    let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    println!("log-log slope of word residual curve: {slope:.2} (power law ⇒ roughly linear, negative)");
    println!("saved fig6_word_residual_rank.csv, fig6_topic_residual_rank.csv");
}
