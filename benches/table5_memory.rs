//! Table 5 — per-processor memory on PUBMED at K = 2000 as a function of
//! N, regenerated from the analytic byte accounting (util::mem) with the
//! paper's real corpus statistics, plus a measured-RSS spot check of the
//! POBP constant-memory claim at bench scale.
//!
//! Expected shape (paper's Table 5): the batch algorithms shrink ~1/N and
//! fail (>2 GB/processor) for small N; POBP is constant in N.
//!
//! Extended for the sharded φ̂ storage mode (`PhiStorageMode::Sharded`):
//! a `pobp_sharded_mb` column (the replica swapped for a row-aligned
//! owner slice + the power working set, O(W·K/N)) and a big-K section
//! (K = 8000) where the dense replica alone exceeds the 2 GB budget —
//! the config only the sharded mode can train.

#[path = "common/mod.rs"]
mod common;

use pobp::metrics::{results_dir, Table};
use pobp::repro::{run_algo, Algo, RunOpts};
use pobp::storage::PhiStorageMode;
use pobp::synth::TABLE3;
use pobp::util::mem::{rss_bytes, MemModel};

fn mb(bytes: usize) -> String {
    format!("{}", bytes / (1 << 20))
}

fn na_if_over(bytes: usize, budget: usize) -> String {
    if bytes > budget {
        "N/A".into()
    } else {
        mb(bytes)
    }
}

fn main() {
    common::banner("Table 5", "memory per processor vs N (PUBMED, K=2000)", "analytic at paper scale + measured RSS check");
    let row = &TABLE3[3];
    let k = 2000;
    let budget = 2 * (1usize << 30); // the paper's 2 GB per processor
    // POBP's mini-batch footprint: NNZ≈45k per batch, docs ≈ NNZ/(nnz per doc)
    let docs_per_batch = 45_000 / (row.nnz as usize / row.d);
    // sharded mode's gathered working set: the paper-default power
    // selection (λ_W·W words × λ_K·K topics)
    let working = (row.w / 10) * 50;

    let mut t = Table::new(
        "table5_memory",
        &["n", "pfgs_mb", "psgs_ylda_mb", "pvb_mb", "pobp_mb", "pobp_sharded_mb"],
    );
    for &n in &[1024usize, 512, 256, 128, 64, 32] {
        let batch = MemModel {
            docs_resident: row.d / n,
            nnz_resident: row.nnz as usize / n,
            tokens_resident: row.tokens as usize / n,
            k,
            w: row.w,
        };
        let pobp = MemModel {
            docs_resident: docs_per_batch / n.min(docs_per_batch).max(1),
            nnz_resident: 45_000 / n.min(45_000),
            tokens_resident: 0,
            k,
            w: row.w,
        };
        // POBP per-processor memory is dominated by the two global K×W
        // matrices — constant in N under replicated storage; the sharded
        // column swaps that replica for the owner slice + working set,
        // so it shrinks ~1/N.
        t.row(&[
            n.to_string(),
            na_if_over(batch.pgs_bytes(), budget),
            na_if_over(batch.pgs_bytes() * 3 / 4, budget), // SGS stores sparse lists
            na_if_over(batch.pvb_bytes(), budget),
            mb(pobp.pobp_bytes()),
            mb(pobp.pobp_sharded_bytes(n, working)),
        ]);
    }
    println!("{}", t.render());
    t.save(&results_dir()).unwrap();

    // --- big K: the sharded mode's reason to exist. At K = 8000 the
    //     dense φ̂ + r replica alone (2·4·W·K ≈ 8.4 GB at PUBMED's W)
    //     blows the 2 GB budget at *every* N — the replicated column is
    //     N/A across the board — while the sharded worker comes under
    //     budget once the owner slice shrinks past the K-proportional
    //     per-nnz message matrix (N ≥ 32 here; at N = 8 messages + slice
    //     still exceed it). ---
    let k_big = 8000;
    let big = MemModel {
        docs_resident: docs_per_batch,
        nnz_resident: 45_000,
        tokens_resident: 0,
        k: k_big,
        w: row.w,
    };
    let mut tb = Table::new(
        "table5_memory_bigk",
        &["n", "pobp_replicated_mb", "pobp_sharded_mb"],
    );
    for &n in &[8usize, 32, 64, 256] {
        tb.row(&[
            n.to_string(),
            na_if_over(big.pobp_bytes(), budget),
            na_if_over(big.pobp_sharded_bytes(n, working), budget),
        ]);
    }
    println!("big K (K={k_big}): replicated needs {} MB of phi+r replica alone", mb(big.phi_replica_bytes()));
    println!("{}", tb.render());
    tb.save(&results_dir()).unwrap();

    // measured spot check at bench scale: POBP RSS is flat in N, and the
    // sharded mode trains the same corpus with per-worker φ̂ cut to the
    // owner slice (whole-process RSS barely moves at bench scale — the
    // claim is per-worker, pinned analytically above and in util::mem)
    let k_small = 50;
    let corpus = common::corpus("enron", k_small, 3);
    let params = common::params(k_small);
    println!("measured whole-process RSS during POBP (bench scale):");
    for n in [2usize, 8, 32] {
        let before = rss_bytes();
        let o = RunOpts { n_workers: n, ..common::opts(n, k_small) };
        let _ = run_algo(Algo::Pobp, &corpus, &params, &o);
        let after = rss_bytes();
        println!("  N={n:3}: rss {} -> {} MB", before / (1 << 20), after / (1 << 20));
    }
    {
        let before = rss_bytes();
        let o = RunOpts {
            n_workers: 8,
            storage: PhiStorageMode::Sharded,
            ..common::opts(8, k_small)
        };
        let _ = run_algo(Algo::Pobp, &corpus, &params, &o);
        let after = rss_bytes();
        println!(
            "  N=  8 (sharded): rss {} -> {} MB",
            before / (1 << 20),
            after / (1 << 20)
        );
    }
    println!("saved table5_memory.csv + table5_memory_bigk.csv");
}
