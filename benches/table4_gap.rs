//! Table 4 — perplexity gap between POBP and PFGS (Eq. 21),
//! gap = (P_PFGS − P_POBP)/P_PFGS × 100%, per dataset and K.
//!
//! Paper: the gap is positive everywhere (POBP better), grows with the
//! corpus size and with K (24% → 67% from NYTIMES/500 to PUBMED/2000).

#[path = "common/mod.rs"]
mod common;

use pobp::corpus::split_tokens;
use pobp::eval::perplexity::predictive_perplexity;
use pobp::eval::gap_percent;
use pobp::metrics::{results_dir, sig, Table};
use pobp::repro::{run_algo, Algo};

fn main() {
    common::banner("Table 4", "perplexity gap POBP vs PFGS (Eq. 21)", "big-3 sims, K sweep");
    let mut t = Table::new("table4_gap", &["dataset", "k", "p_pobp", "p_pfgs", "gap_percent"]);
    for name in common::BIG3 {
        for &k in &common::K_SWEEP {
            let corpus = common::corpus(name, k, 4);
            let params = common::params(k);
            let split = split_tokens(&corpus, 0.2, 4);
            let o = common::opts(256, k);
            let p_pobp = {
                let r = run_algo(Algo::Pobp, &split.train, &params, &o);
                predictive_perplexity(&r.model, &split, &params, 20, 4)
            };
            let p_pfgs = {
                let r = run_algo(Algo::Pfgs, &split.train, &params, &o);
                predictive_perplexity(&r.model, &split, &params, 20, 4)
            };
            let gap = gap_percent(p_pfgs, p_pobp);
            t.row(&[
                name.to_string(),
                k.to_string(),
                sig(p_pobp),
                sig(p_pfgs),
                format!("{gap:.2}%"),
            ]);
            println!("{name} K={k}: pobp={} pfgs={} gap={gap:.2}%", sig(p_pobp), sig(p_pfgs));
        }
    }
    println!();
    println!("{}", t.render());
    t.save(&results_dir()).unwrap();
    println!("saved table4_gap.csv");
}
