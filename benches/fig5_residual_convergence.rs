//! Fig. 5 — residual and predictive perplexity as a function of iteration
//! on ENRON: the two curves must share the same downward trend, which is
//! the justification for using the residual as the convergence criterion
//! (Fig. 4 line 26).
//!
//! Paper setting: ENRON, K = 500. Here: enron-sim (D/100), K = 50.

#[path = "common/mod.rs"]
mod common;

use pobp::coordinator::{fit, PobpConfig};
use pobp::corpus::split_tokens;
use pobp::eval::perplexity::predictive_perplexity;
use pobp::metrics::{results_dir, sig, Table};
use pobp::sched::PowerParams;

fn main() {
    common::banner("Fig 5", "residual vs predictive perplexity per iteration", "enron-sim, K=50");
    let k = 50;
    let corpus = common::corpus("enron", k, 5);
    let params = common::params(k);
    let split = split_tokens(&corpus, 0.2, 5);

    let cfg = PobpConfig {
        n_workers: 1,
        nnz_budget: usize::MAX, // batch mode so iterations line up
        power: PowerParams::full(),
        max_iters: 60,
        converge_thresh: 0.0,
        snapshot_every: 1,
        ..Default::default()
    };
    let r = fit(&split.train, &params, &cfg);

    let mut t = Table::new("fig5_residual_convergence", &["iter", "residual_per_token", "perplexity"]);
    for (st, (_, model)) in r.history.iter().zip(&r.snapshots) {
        let perp = predictive_perplexity(model, &split, &params, 15, 7);
        t.row(&[st.iter.to_string(), sig(st.residual_per_token), sig(perp)]);
    }
    println!("{}", t.render());
    let path = t.save(&results_dir()).unwrap();
    println!("saved {}", path.display());

    // the paper's claim: both curves trend down together — compare the
    // start (t = 1, before the random-init dip/hump documented in
    // DESIGN.md §Calibration) with the converged tail
    let first_r: f64 = t.rows[0][1].parse().unwrap();
    let last_r: f64 = t.rows.last().unwrap()[1].parse().unwrap();
    let first_p: f64 = t.rows[0][2].parse().unwrap();
    let last_p: f64 = t.rows.last().unwrap()[2].parse().unwrap();
    println!(
        "\nresidual {} -> {}, perplexity {} -> {}  (co-trending: {})",
        sig(first_r), sig(last_r), sig(first_p), sig(last_p),
        last_r < first_r && last_p < first_p
    );
}
