//! Fig. 9 + Table 4 input — final predictive perplexity of all algorithms
//! on the three big corpora for the K sweep.
//!
//! Paper setting: K ∈ {500, 1000, 2000}, N = 256. Here: K ∈ {25, 50, 100}
//! on the Table-3-scaled corpora. Expected shape: POBP lowest everywhere;
//! GS family close together; PVB highest and worsening with K.

#[path = "common/mod.rs"]
mod common;

use pobp::corpus::split_tokens;
use pobp::eval::perplexity::predictive_perplexity;
use pobp::metrics::{results_dir, sig, Table};
use pobp::repro::{run_algo, Algo};

fn main() {
    common::banner("Fig 9", "final perplexity, all algos x K sweep", "big-3 sims, K in {25,50,100}, N=256");
    let mut t = Table::new("fig9_accuracy", &["dataset", "k", "algo", "perplexity"]);
    for name in common::BIG3 {
        for &k in &common::K_SWEEP {
            let corpus = common::corpus(name, k, 9);
            let params = common::params(k);
            let split = split_tokens(&corpus, 0.2, 9);
            print!("{name} K={k}: ");
            for algo in Algo::paper_set() {
                let o = common::opts(256, k);
                let r = run_algo(algo, &split.train, &params, &o);
                let perp = predictive_perplexity(&r.model, &split, &params, 20, 9);
                t.row(&[name.to_string(), k.to_string(), algo.name().to_string(), sig(perp)]);
                print!("{}={} ", algo.name(), sig(perp));
            }
            println!();
        }
    }
    println!();
    println!("{}", t.render());
    t.save(&results_dir()).unwrap();
    println!("saved fig9_accuracy.csv (table4_gap consumes this)");
}
