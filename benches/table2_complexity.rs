//! Table 2 — analytic complexity comparison of POBP / OBP / PGS,
//! instantiated with the paper's real corpus statistics, plus an
//! empirical check that the measured per-iteration costs scale the way
//! the formulas say.
//!
//! ```text
//! algorithm  computation           memory                      communication
//! POBP       η λK λW K W D T / N   K(ηWD + D)/(MN) + 2KW       λK λW K W M N T
//! OBP        η λK λW K W D T       K(ηWD + D)/M + 2KW          —
//! PGS        η' K W D T' / N       (KD + η'WD)/N + KW          N K W T'
//! ```

#[path = "common/mod.rs"]
mod common;

use pobp::metrics::{results_dir, sig, Table};
use pobp::repro::{run_algo, Algo, RunOpts};
use pobp::synth::TABLE3;

fn main() {
    common::banner("Table 2", "complexity formulas instantiated (PUBMED, paper scale)", "analytic + empirical scaling check");

    // paper-scale instantiation on PUBMED
    let row = &TABLE3[3];
    let (d, w) = (row.d as f64, row.w as f64);
    let eta = row.nnz as f64 / (w * d);
    let eta_p = row.tokens as f64 / (w * d);
    let (k, t_online, t_batch) = (2000f64, 200f64, 500f64);
    let (lam_w, lam_kk) = (0.1, 50.0);
    let lam_k = lam_kk / k;
    let n = 256f64;
    // NNZ ≈ 45,000 *per processor* per mini-batch (§4) — the paper's
    // M = 19 for PUBMED at N = 256
    let m = (row.nnz as f64 / (45_000.0 * n)).ceil();

    let mut t = Table::new(
        "table2_complexity",
        &["algorithm", "computation_ops", "memory_elems_per_proc", "comm_elems_total"],
    );
    let pobp_comp = eta * lam_k * lam_w * k * w * d * t_online / n;
    let pobp_mem = k * (eta * w * d + d) / (m * n) + 2.0 * k * w;
    let pobp_comm = lam_k * lam_w * k * w * m * n * t_online;
    t.row(&["POBP".into(), sig(pobp_comp), sig(pobp_mem), sig(pobp_comm)]);
    let obp_comp = eta * lam_k * lam_w * k * w * d * t_online;
    let obp_mem = k * (eta * w * d + d) / m + 2.0 * k * w;
    t.row(&["OBP".into(), sig(obp_comp), sig(obp_mem), "0".into()]);
    let pgs_comp = eta_p * k * w * d * t_batch / n;
    let pgs_mem = (k * d + eta_p * w * d) / n + k * w;
    let pgs_comm = n * k * w * t_batch;
    t.row(&["PGS".into(), sig(pgs_comp), sig(pgs_mem), sig(pgs_comm)]);
    println!("{}", t.render());
    println!(
        "POBP/PGS communication ratio: {:.4} (the paper's orders-of-magnitude claim)",
        pobp_comm / pgs_comm
    );
    t.save(&results_dir()).unwrap();

    // empirical check at bench scale: communication elements per sync
    let k_small = 50;
    let corpus = common::corpus("enron", k_small, 2);
    let params = common::params(k_small);
    let o = RunOpts { n_workers: 8, ..common::opts(8, k_small) };
    let pobp = run_algo(Algo::Pobp, &corpus, &params, &o);
    let pgs = run_algo(Algo::Pgs, &corpus, &params, &o);
    let pobp_per_sync =
        pobp.ledger.payload_bytes_total() as f64 / pobp.ledger.sync_count() as f64;
    let pgs_per_sync =
        pgs.ledger.payload_bytes_total() as f64 / pgs.ledger.sync_count() as f64;
    println!(
        "\nempirical payload/sync: pobp {} B, pgs {} B, ratio {:.3} \
         (formula λKλW·2 = {:.3}; t=1 full syncs raise the measured ratio)",
        sig(pobp_per_sync),
        sig(pgs_per_sync),
        pobp_per_sync / pgs_per_sync,
        2.0 * 0.1 * (o.power.lambda_k_times_k as f64 / k_small as f64),
    );
    println!("saved table2_complexity.csv");
}
